//! Cost-weighted admission from the outside: jobs are priced in work
//! units via `MethodSpec::cost` (full-matrix methods ~ n^2, OneBatchPAM
//! ~ n*m), the old `FULL_MATRIX_LIMIT` rule is the pricing ceiling, and
//! the server's weighted budget admits many cheap OneBatch jobs
//! concurrently while an over-budget full-matrix request is rejected
//! immediately — before any dataset I/O when the source predicts its
//! rows (catalogue names, `file:...?rows=N` hints).

use obpam::server::{
    handle_line, request, serve, AdmissionPermit, CacheStats, ServerConfig, ServerState,
};
use obpam::solver::{MethodSpec, FULL_MATRIX_LIMIT, MAX_JOB_COST};

fn state_with_budget(budget: u64) -> ServerState {
    ServerState::new(&ServerConfig { budget, ..Default::default() })
}

#[test]
fn pricing_subsumes_the_full_matrix_limit() {
    // the one-off limit check is now a special case of pricing: a
    // quadratic method is admissible exactly up to FULL_MATRIX_LIMIT
    let fp = MethodSpec::FasterPam;
    assert!(fp.cost(FULL_MATRIX_LIMIT, 10, None).admissible());
    assert!(!fp.cost(FULL_MATRIX_LIMIT + 1, 10, None).admissible());
    assert_eq!(fp.cost(FULL_MATRIX_LIMIT, 10, None).units, MAX_JOB_COST);
    // linear methods are admissible at any paper scale
    assert!(MethodSpec::default().cost(5_000_000, 100, None).admissible());
}

#[test]
fn rows_hint_prices_the_job_before_any_io() {
    // the path does not exist: with a rows hint, both the feasibility
    // ceiling and the budget apply on the hint alone — rejection must
    // happen with zero stat/load (the cache counters stay zeroed and
    // the error is about cost, not about the missing file)
    let st = state_with_budget(1_000_000);
    let _held = st.admission.try_admit(900_000).unwrap();
    let line = "cluster dataset=file:/definitely/not/here.csv?rows=2000 k=5 method=FasterPAM";
    let r = handle_line(&st, line);
    assert!(r.starts_with("err over budget"), "{r}");
    let expect = MethodSpec::FasterPam.cost(2000, 5, None).units;
    assert!(r.contains(&format!("cost={expect}")), "{r}");
    assert_eq!(st.cache.stats(), CacheStats::default(), "no I/O for a rejected job");
}

#[test]
fn full_budget_of_cheap_jobs_rejects_expensive_admits_cheap() {
    // the acceptance scenario: the budget is mostly held by in-flight
    // cheap OneBatch jobs; a further cheap OneBatch request is admitted
    // concurrently, while an admissible-but-over-budget full-matrix
    // request gets an immediate err carrying its computed cost
    let st = state_with_budget(600_000);
    let cheap = MethodSpec::default().cost(300, 3, None).units; // 300 * 300
    assert_eq!(cheap, 90_000);
    let permits: Vec<AdmissionPermit<'_>> =
        (0..5).map(|_| st.admission.try_admit(cheap).unwrap()).collect();
    assert_eq!(st.admission.used(), 450_000);

    // cheap OneBatch: fits the remaining budget, runs to completion
    let ok = handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=1");
    assert!(ok.starts_with("ok "), "{ok}");
    assert!(ok.contains(&format!(" cost={cheap}")), "{ok}");

    // full-matrix at n=1500: admissible per-job (1500 <= limit) but its
    // 2.25M units exceed the 150k free -> immediate err, no I/O
    let fp_line = "cluster dataset=file:/definitely/not/here.csv?rows=1500 k=5 method=FasterPAM";
    let r = handle_line(&st, fp_line);
    assert!(r.starts_with("err over budget"), "{r}");
    assert!(r.contains("cost=2250000"), "{r}");
    // only the successful cheap job touched the cache
    let s = st.cache.stats();
    assert_eq!((s.misses, s.entries), (1, 1), "{s:?}");

    // once the cheap jobs finish, the budget idles; the idle exception
    // lets the oversized job in, so now the request fails on the
    // missing file (i.e. admission is no longer what stops it)
    drop(permits);
    assert_eq!(st.admission.used(), 0);
    let r2 = handle_line(&st, fp_line);
    assert!(r2.starts_with("err"), "{r2}");
    assert!(!r2.contains("over budget"), "{r2}");
}

#[test]
fn infeasible_methods_report_cost_in_the_rejection() {
    let st = state_with_budget(0);
    let r = handle_line(
        &st,
        "cluster dataset=file:/nope.csv?rows=50000 k=5 method=FasterPAM",
    );
    assert!(r.starts_with("err"), "{r}");
    assert!(r.contains("infeasible at n=50000"), "{r}");
    assert!(r.contains("cost=2500000000"), "{r}");
    assert_eq!(st.cache.stats(), CacheStats::default());
}

#[test]
fn lying_rows_hint_is_repriced_after_the_load() {
    // the ?rows= hint is client-supplied and never validated against
    // the file: a hint claiming 100 rows must not smuggle a full-matrix
    // job over a FULL_MATRIX_LIMIT+1-row CSV past the pricing ceiling —
    // the post-load reprice at the actual row count catches it
    let dir = std::env::temp_dir().join("obpam_admission_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("lying_{}.csv", std::process::id()));
    let rows = FULL_MATRIX_LIMIT + 1;
    let mut csv = String::from("a,b\n");
    for i in 0..rows {
        csv.push_str(&format!("{}.0,{}.5\n", i % 7, (i * 3) % 5));
    }
    std::fs::write(&path, csv).unwrap();
    let st = ServerState::new(&ServerConfig::default());
    let r = handle_line(
        &st,
        &format!("cluster dataset=file:{}?rows=100 k=5 method=FasterPAM", path.display()),
    );
    assert!(r.starts_with("err"), "{r}");
    assert!(r.contains(&format!("infeasible at n={rows}")), "{r}");
    assert_eq!(st.admission.used(), 0, "the provisional permit must be released");
    // an honest linear-cost job over the same oversized file still runs
    let ok = handle_line(&st, &format!("cluster dataset=file:{} k=5 m=50", path.display()));
    assert!(ok.starts_with("ok "), "{ok}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn strict_budget_rejects_oversized_lone_jobs() {
    // default (v4) behaviour: an idle budget admits one oversized job
    let lax = state_with_budget(1_000);
    let line = "cluster dataset=blobs_300_4_3 k=3 seed=1"; // ~90k units
    assert!(handle_line(&lax, line).starts_with("ok "), "idle exception admits a lone job");

    // ServerConfig::strict_budget turns the budget into a hard ceiling
    let strict = ServerState::new(&ServerConfig {
        budget: 1_000,
        strict_budget: true,
        ..Default::default()
    });
    let r = handle_line(&strict, line);
    assert!(r.starts_with("err over budget"), "{r}");
    assert!(r.contains("cost="), "{r}");
    assert_eq!(strict.admission.used(), 0);
    assert_eq!(strict.cache.stats(), CacheStats::default(), "no I/O for a rejected job");
    // within-budget jobs still run under strict
    let small = ServerState::new(&ServerConfig {
        budget: 200_000,
        strict_budget: true,
        ..Default::default()
    });
    assert!(handle_line(&small, line).starts_with("ok "));
    assert_eq!(small.admission.used(), 0);
}

#[test]
fn concurrent_burst_over_a_tight_budget_stays_consistent() {
    // a real TCP burst against a budget sized for about one job at a
    // time: every connection gets exactly one well-formed reply (ok
    // with cost=, or err over budget with cost=), at least one job is
    // served, and the budget fully drains afterwards
    let cheap = MethodSpec::default().cost(300, 3, None).units;
    let h = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 4,
        queue_cap: 16,
        budget: cheap + cheap / 2,
        ..Default::default()
    })
    .unwrap();
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let addr = h.addr;
            std::thread::spawn(move || {
                request(addr, &format!("cluster dataset=blobs_300_4_3 k=3 seed={}", i % 2))
                    .unwrap()
            })
        })
        .collect();
    let replies: Vec<String> = handles.into_iter().map(|t| t.join().unwrap()).collect();
    let served = replies.iter().filter(|r| r.starts_with("ok ")).count();
    for r in &replies {
        assert!(
            r.starts_with("ok ") || r.starts_with("err over budget"),
            "unexpected reply: {r}"
        );
        assert!(r.contains("cost="), "every decision is priced: {r}");
    }
    assert!(served >= 1, "at least one job must be admitted: {replies:?}");
    assert_eq!(h.state.admission.used(), 0, "budget must drain when jobs finish");
    h.shutdown();
}
