//! Protocol-v3 wire surface: URI-addressed dataset sources end-to-end.
//!
//! Covers the new `DataSource` pipeline from the outside: URI
//! round-trips, `metric=` / `scale_features=` validation, `file:`
//! datasets served through the sharded cache (miss-then-hit with
//! identical medoids), fingerprint invalidation when the file changes on
//! disk, and a full TCP smoke test (the CI end-to-end step).

use obpam::data::DataSource;
use obpam::server::{handle_line, request, serve, ServerConfig, ServerState};
use std::path::PathBuf;

fn fresh_state() -> ServerState {
    ServerState::new(&ServerConfig::default())
}

/// Write a small 3-cluster CSV (header + `rows` numeric lines) and
/// return its path.  Content is deterministic in `rows`.
fn temp_csv(tag: &str, rows: usize) -> PathBuf {
    let dir = std::env::temp_dir().join("obpam_wire_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}_{}.csv", std::process::id()));
    let mut s = String::from("x,y\n");
    for i in 0..rows {
        let c = (i % 3) as f64 * 25.0;
        s.push_str(&format!("{},{}\n", c + (i % 7) as f64 * 0.3, c - (i % 5) as f64 * 0.2));
    }
    std::fs::write(&path, s).unwrap();
    path
}

fn medoids_of(reply: &str) -> String {
    reply.split("medoids=").nth(1).unwrap().split_whitespace().next().unwrap().to_string()
}

#[test]
fn uri_parse_canon_round_trip() {
    for (input, canon) in [
        ("abalone", "synth:abalone"),
        ("synth:abalone", "synth:abalone"),
        ("blobs_2000_8_5", "synth:blobs_2000_8_5"),
        ("file:/data/points.csv", "file:/data/points.csv"),
        ("file:/data/points.csv?rows=416153", "file:/data/points.csv?rows=416153"),
    ] {
        let src = DataSource::parse(input).unwrap();
        assert_eq!(src.canon(), canon, "{input}");
        assert_eq!(DataSource::parse(&src.canon()).unwrap(), src, "{input} canon round-trip");
    }
    for bad in ["", "s3:bucket/key", "synth:", "file:", "file:/x.csv?rows=nope"] {
        assert!(DataSource::parse(bad).is_err(), "{bad:?} should not parse");
    }
}

#[test]
fn metric_accepted_and_rejected_on_the_wire() {
    let st = fresh_state();
    // every metric the native backend evaluates is wire-addressable
    for metric in ["l1", "l2", "sqeuclidean", "chebyshev", "cosine"] {
        let r = handle_line(&st, &format!("cluster dataset=blobs_300_4_3 k=3 seed=1 metric={metric}"));
        assert!(r.starts_with("ok "), "{metric}: {r}");
    }
    // unknown spellings are protocol errors, not silent L1 fallbacks
    for bad in ["bogus", "l3", "L1 "] {
        let r = handle_line(&st, &format!("cluster dataset=blobs_300_4_3 k=3 metric={bad}"));
        assert!(r.starts_with("err"), "{bad}: {r}");
    }
}

#[test]
fn file_cluster_miss_then_hit_identical_medoids() {
    let path = temp_csv("hit", 60);
    let st = fresh_state();
    let line = format!("cluster dataset=file:{} metric=l2 k=3 seed=4", path.display());
    let first = handle_line(&st, &line);
    let second = handle_line(&st, &line);
    assert!(first.starts_with("ok "), "{first}");
    assert!(first.contains("cache=miss"), "{first}");
    assert!(second.contains("cache=hit"), "{second}");
    assert_eq!(medoids_of(&first), medoids_of(&second));
    assert!(first.contains(&format!(" source=file:{}", path.display())), "{first}");
    let s = st.cache.stats();
    assert_eq!((s.hits, s.misses), (1, 1));
    std::fs::remove_file(&path).ok();
}

#[test]
fn fingerprint_invalidation_when_file_changes_on_disk() {
    let path = temp_csv("inval", 50);
    let st = fresh_state();
    let line = format!("cluster dataset=file:{} metric=l2 k=3 seed=4", path.display());
    assert!(handle_line(&st, &line).contains("cache=miss"));
    assert!(handle_line(&st, &line).contains("cache=hit"));
    // rewrite the file with different content (row count changes the
    // size, so the fingerprint flips regardless of mtime granularity)
    std::fs::remove_file(&path).ok();
    let path2 = temp_csv("inval", 55);
    assert_eq!(path, path2, "same path, new bytes");
    let third = handle_line(&st, &line);
    assert!(third.contains("cache=miss"), "edited file must reload: {third}");
    // and the refreshed entry is hit again afterwards
    assert!(handle_line(&st, &line).contains("cache=hit"));
    let s = st.cache.stats();
    assert_eq!(s.misses, 2, "exactly one reload after the edit");
    std::fs::remove_file(&path).ok();
}

#[test]
fn scale_features_is_validated_and_cached_separately() {
    let path = temp_csv("scalef", 40);
    let st = fresh_state();
    let base = format!("cluster dataset=file:{} metric=l2 k=3 seed=1", path.display());
    assert!(handle_line(&st, &base).starts_with("ok "));
    let scaled = handle_line(&st, &format!("{base} scale_features=minmax"));
    assert!(scaled.starts_with("ok "), "{scaled}");
    assert!(scaled.contains("cache=miss"), "scaled variant is its own entry: {scaled}");
    assert!(handle_line(&st, &format!("{base} scale_features=bogus")).starts_with("err"));
    assert_eq!(st.cache.stats().entries, 2);
    std::fs::remove_file(&path).ok();
}

#[test]
fn legacy_bare_name_requests_keep_v2_reply_shape() {
    let st = fresh_state();
    let r = handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=5");
    // v2 prefix byte-for-byte, then v3's source= and v4's cost= appended
    assert!(r.starts_with("ok method=OneBatch-nniw cache=miss medoids="), "{r}");
    for field in ["objective=", "seconds=", "dissim=", "swaps="] {
        assert!(r.contains(field), "{field}: {r}");
    }
    assert!(r.contains(" source=synth:blobs_300_4_3 cost="), "{r}");
    let cost: u64 =
        r.split(" cost=").nth(1).unwrap().split_whitespace().next().unwrap().parse().unwrap();
    assert!(cost > 0, "{r}");
    // v6 appends the final assignment pass's inertia after cost=
    assert!(r.contains(" inertia="), "{r}");
    // the schemed spelling of the same dataset shares the cache entry
    let schemed = handle_line(&st, "cluster dataset=synth:blobs_300_4_3 k=3 seed=5");
    assert!(schemed.contains("cache=hit"), "{schemed}");
    assert_eq!(medoids_of(&r), medoids_of(&schemed));
}

#[test]
fn stats_aggregates_per_method_across_file_and_synth() {
    let path = temp_csv("stats", 40);
    let st = fresh_state();
    let file_line = format!("cluster dataset=file:{} metric=l2 k=3 seed=1", path.display());
    assert!(handle_line(&st, &file_line).starts_with("ok "));
    assert!(handle_line(&st, &file_line).starts_with("ok "));
    assert!(handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 method=k-means++").starts_with("ok "));
    let stats = handle_line(&st, "stats");
    assert!(stats.starts_with("ok cache_hits=1 cache_misses=2 cache_entries=2"), "{stats}");
    assert!(stats.contains("method.OneBatch-nniw.count=2"), "{stats}");
    assert!(stats.contains("method.k-means++.count=1"), "{stats}");
    assert!(stats.contains("method.k-means++.ms_mean="), "{stats}");
    assert!(stats.contains("method.OneBatch-nniw.dissim_max="), "{stats}");
    std::fs::remove_file(&path).ok();
}

/// Protocol v5 lifts the documented v4 limitation that whitespace-
/// tokenized request lines could not address `file:` paths containing
/// spaces: a double-quoted value keeps its spaces through the
/// tokenizer, both inline and over real TCP.
#[test]
fn quoted_file_paths_with_spaces_are_wire_addressable() {
    let dir = std::env::temp_dir().join(format!("obpam wire spaces {}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("my points.csv");
    let mut s = String::from("x,y\n");
    for i in 0..60 {
        let c = (i % 3) as f64 * 25.0;
        s.push_str(&format!("{},{}\n", c + (i % 7) as f64 * 0.3, c - (i % 5) as f64 * 0.2));
    }
    std::fs::write(&path, s).unwrap();

    let st = fresh_state();
    let line = format!("cluster dataset=\"file:{}\" metric=l2 k=3 seed=4", path.display());
    let first = handle_line(&st, &line);
    assert!(first.starts_with("ok "), "{first}");
    assert!(first.contains(&format!(" source=file:{}", path.display())), "{first}");
    // the quoted spelling shares the cache entry with itself
    let second = handle_line(&st, &line);
    assert!(second.contains("cache=hit"), "{second}");
    assert_eq!(medoids_of(&first), medoids_of(&second));
    // unquoted, the path splits into junk tokens -> an error, never a
    // silent wrong-file load
    let unquoted = format!("cluster dataset=file:{} metric=l2 k=3 seed=4", path.display());
    assert!(handle_line(&st, &unquoted).starts_with("err"), "unquoted spaces cannot resolve");
    // an unterminated quote is a protocol error
    let ragged = format!("cluster dataset=\"file:{} k=3", path.display());
    assert!(handle_line(&st, &ragged).starts_with("err unterminated"), "{ragged}");

    // and over real TCP, end to end
    let h = serve(ServerConfig::default()).unwrap();
    let wire = request(h.addr, &line).unwrap();
    assert!(wire.starts_with("ok "), "{wire}");
    assert_eq!(medoids_of(&first), medoids_of(&wire), "{wire}");
    h.shutdown();
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir(&dir).ok();
}

/// CI end-to-end smoke: write a CSV, start the real TCP server, drive
/// `cluster dataset=file:... metric=l2 k=3` twice over the wire, and
/// require a cache hit with identical medoids on the second request.
/// CI runs this under an `OBPAM_THREADS` matrix (1 and 4) so every push
/// exercises the persistent pool's reuse determinism end to end.
#[test]
fn e2e_smoke_file_dataset_through_tcp_server() {
    let threads: usize =
        std::env::var("OBPAM_THREADS").ok().and_then(|s| s.parse().ok()).unwrap_or(2);
    let path = temp_csv("e2e", 80);
    let h = serve(ServerConfig::default()).unwrap();
    let line = format!(
        "cluster dataset=file:{} metric=l2 k=3 seed=7 threads={threads}",
        path.display()
    );
    let first = request(h.addr, &line).unwrap();
    let second = request(h.addr, &line).unwrap();
    assert!(first.starts_with("ok "), "{first}");
    assert!(first.contains("cache=miss"), "{first}");
    assert!(second.contains("cache=hit"), "{second}");
    assert_eq!(medoids_of(&first), medoids_of(&second));
    // medoids are thread-count independent: a serial run over the same
    // wire selects the same rows the threaded run did
    let serial = request(
        h.addr,
        &format!("cluster dataset=file:{} metric=l2 k=3 seed=7 threads=1", path.display()),
    )
    .unwrap();
    assert_eq!(medoids_of(&first), medoids_of(&serial), "{serial}");
    // v4 reply fields reach the wire on every served connection
    assert!(first.contains(" cost="), "{first}");
    assert!(first.contains(" queue_ms="), "{first}");
    // and the stats surface saw exactly this traffic
    let stats = request(h.addr, "stats").unwrap();
    assert!(stats.starts_with("ok cache_hits=2 cache_misses=1"), "{stats}");
    assert!(stats.contains("method.OneBatch-nniw.count=3"), "{stats}");
    assert!(stats.contains("method.OneBatch-nniw.ms_hist="), "{stats}");
    assert!(stats.contains("method.OneBatch-nniw.queue_hist="), "{stats}");
    // stats reset re-bases the counters over the wire, too
    assert!(request(h.addr, "stats reset").unwrap().starts_with("ok"));
    let after = request(h.addr, "stats").unwrap();
    assert!(after.starts_with("ok cache_hits=0 cache_misses=0"), "{after}");
    h.shutdown();
    std::fs::remove_file(&path).ok();
}
