//! The protocol v8 evented accept core from the outside: idle-waiter
//! scaling at a constant server thread count, pipelined requests on a
//! persistent connection, a slow client not stalling its neighbours,
//! timer-wheel deadline sheds on an unbounded `wait`, connection-cap
//! admission, connection telemetry in `stats`, CLARA cancellation
//! releasing its admission permit, and byte-compat field walks for the
//! v1–v7 reply shapes over the new loop.

use obpam::server::{request, serve, ServerConfig};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Extract `key=<token>` from a reply line.
fn field(reply: &str, key: &str) -> String {
    reply
        .split_whitespace()
        .find_map(|t| t.strip_prefix(&format!("{key}=")))
        .unwrap_or_else(|| panic!("no {key}= in {reply:?}"))
        .to_string()
}

/// Poll `job` on `addr` until its state leaves `queued` (worker pickup)
/// or the attempts run out; returns the last observed state.
fn poll_until_past_queued(addr: std::net::SocketAddr, job: &str) -> String {
    for _ in 0..20_000 {
        let r = request(addr, &format!("poll job={job}")).unwrap();
        let state = field(&r, "state");
        if state != "queued" {
            return state;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    panic!("job {job} never left the queue");
}

/// The process's live thread count (`Threads:` in /proc/self/status) —
/// the server runs in-process, so a per-connection thread anywhere
/// would show up here.
#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").unwrap();
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .expect("Threads: line in /proc/self/status")
}

/// Raise the soft fd limit toward the hard one (best effort) so a
/// thousand concurrent sockets fit under a conservative default ulimit.
#[cfg(target_os = "linux")]
fn raise_fd_limit() {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    const RLIMIT_NOFILE: i32 = 7;
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    let mut lim = RLimit { cur: 0, max: 0 };
    // SAFETY: `lim` is a valid repr(C) rlimit the kernel fills.
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return;
    }
    let want = 16_384.min(lim.max);
    if lim.cur < want {
        lim.cur = want;
        // SAFETY: `lim` is a valid repr(C) rlimit; cur <= max by
        // construction, so the call can only shrink-or-fail cleanly.
        let _ = unsafe { setrlimit(RLIMIT_NOFILE, &lim) };
    }
}

/// One persistent raw connection: write request lines yourself, read
/// replies in order.
fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn read_reply(reader: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim().to_string()
}

/// The tentpole acceptance test: >= 1000 concurrent blocked `wait`
/// connections at a *constant* process thread count, every one resolved
/// by a single terminal transition through the self-pipe wakeup.
#[cfg(target_os = "linux")]
#[test]
fn thousand_idle_waiters_at_constant_thread_count() {
    const WAITERS: usize = 1000;
    raise_fd_limit();
    let h = serve(ServerConfig { workers: 1, queue_cap: 8, ..Default::default() }).unwrap();

    // a long CLARA blocker occupies the lone worker (3000 subsample
    // reps — many seconds of work, but cancellable between reps, so
    // the test never pays the full solve); a cheap job queues behind
    // it and cannot reach a terminal state while the waiters park
    let blocker = request(
        h.addr,
        "submit dataset=blobs_20000_8_5 k=5 seed=3 method=FasterCLARA-3000",
    )
    .unwrap();
    assert!(blocker.starts_with("ok job="), "{blocker}");
    let blocker_id = field(&blocker, "job");
    assert_eq!(poll_until_past_queued(h.addr, &blocker_id), "running");
    let parked = request(h.addr, "submit dataset=blobs_300_4_3 k=3 seed=4").unwrap();
    assert!(parked.starts_with("ok job="), "{parked}");
    let parked_id = field(&parked, "job");

    let baseline = thread_count();
    let mut conns = Vec::with_capacity(WAITERS);
    for _ in 0..WAITERS {
        let (mut stream, reader) = connect(h.addr);
        writeln!(stream, "wait job={parked_id} timeout_ms=600000").unwrap();
        conns.push((stream, reader));
    }
    // stats round-trips on fresh connections prove cheap verbs are
    // served while the waiters sit blocked; poll until the loop has
    // parked every one (their request bytes may still be in flight)
    let mut stats = String::new();
    for _ in 0..20_000 {
        stats = request(h.addr, "stats").unwrap();
        if field(&stats, "waiters").parse::<usize>().unwrap() == WAITERS {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(field(&stats, "waiters").parse::<usize>().unwrap(), WAITERS, "{stats}");
    assert!(
        field(&stats, "conns").parse::<usize>().unwrap() >= WAITERS,
        "every waiter holds a connection: {stats}"
    );
    assert_eq!(
        thread_count(),
        baseline,
        "parking {WAITERS} waiters must not spawn a single thread"
    );

    // one terminal transition resolves all of them: cancel the queued
    // job (deterministic — no cooperative race with a running solve);
    // its stored result is the reply every waiter receives
    let c = request(h.addr, &format!("cancel job={parked_id}")).unwrap();
    assert!(c.contains("state=cancelled"), "{c}");
    for (_, reader) in conns.iter_mut() {
        let r = read_reply(reader);
        assert!(r.starts_with(&format!("err cancelled job={parked_id}")), "{r}");
        assert!(r.contains(" queue_ms=") && r.contains(" served_ms="), "{r}");
    }
    drop(conns);
    // cancel the CLARA blocker too (the ROADMAP 5b token check lands
    // between subsample reps) and confirm the budget fully drains
    let c = request(h.addr, &format!("cancel job={blocker_id}")).unwrap();
    assert!(
        c.contains("cancel=requested") || c.contains("state=done") || c.contains("state=cancelled"),
        "{c}"
    );
    let fin = request(h.addr, &format!("wait job={blocker_id} timeout_ms=600000")).unwrap();
    assert!(
        fin.starts_with(&format!("err cancelled job={blocker_id}")) || fin.starts_with("ok method="),
        "{fin}"
    );
    assert_eq!(h.state.admission.used(), 0);
    h.shutdown();
}

#[test]
fn pipelined_submits_on_one_connection_reply_in_order() {
    let h = serve(ServerConfig { workers: 2, ..Default::default() }).unwrap();
    let (mut stream, mut reader) = connect(h.addr);
    // one write, five requests: the loop must answer strictly in order
    stream
        .write_all(
            b"ping\n\
              submit dataset=blobs_300_4_3 k=3 seed=1\n\
              submit dataset=blobs_300_4_3 k=3 seed=2\n\
              submit dataset=blobs_300_4_3 k=3 seed=3\n\
              jobs\n",
        )
        .unwrap();
    let replies: Vec<String> = (0..5).map(|_| read_reply(&mut reader)).collect();
    assert!(replies[0].starts_with("pong"), "{:?}", replies[0]);
    for (i, r) in replies[1..4].iter().enumerate() {
        assert!(r.starts_with(&format!("ok job=j{} cost=", i + 1)), "reply {i}: {r}");
    }
    assert!(replies[4].starts_with("ok queued="), "{:?}", replies[4]);

    // the pipelined jobs all complete, on the same connection
    for id in ["j1", "j2", "j3"] {
        writeln!(stream, "wait job={id} timeout_ms=60000").unwrap();
    }
    for id in ["j1", "j2", "j3"] {
        let r = read_reply(&mut reader);
        assert!(r.starts_with("ok method="), "{id}: {r}");
    }
    let stats = request(h.addr, "stats").unwrap();
    assert!(
        field(&stats, "pipelined").parse::<u64>().unwrap() >= 7,
        "2nd..8th request on one connection count as pipelined: {stats}"
    );
    h.shutdown();
}

#[test]
fn slow_client_does_not_stall_other_connections() {
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    // a half-written request: under the old blocking loop this held a
    // connection thread inside read_line; the evented loop just keeps
    // the partial bytes buffered
    let (mut slow, mut slow_reader) = connect(h.addr);
    slow.write_all(b"sta").unwrap();
    slow.flush().unwrap();

    // meanwhile other clients are served promptly
    for _ in 0..20 {
        assert!(request(h.addr, "ping").unwrap().starts_with("pong"));
    }
    let r = request(h.addr, "cluster dataset=blobs_300_4_3 k=3 seed=1").unwrap();
    assert!(r.starts_with("ok method="), "{r}");

    // the slow client finishes its line and still gets a full reply
    slow.write_all(b"ts\n").unwrap();
    let stats = read_reply(&mut slow_reader);
    assert!(stats.starts_with("ok cache_hits="), "{stats}");
    h.shutdown();
}

#[test]
fn unbounded_wait_is_resolved_by_the_deadline_timer() {
    // one worker, occupied: a queued job with a 1 ms deadline is shed
    // by the timer wheel while the `wait` has *no* timeout_ms= — only
    // the deadline timer can resolve it (no worker ever touches it)
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    let big = request(h.addr, "submit dataset=blobs_20000_8_5 k=5 seed=3").unwrap();
    let big_id = field(&big, "job");
    assert_eq!(poll_until_past_queued(h.addr, &big_id), "running");

    let cheap = request(h.addr, "submit dataset=blobs_300_4_3 k=3 seed=1 deadline_ms=1").unwrap();
    let cheap_id = field(&cheap, "job");
    let shed = request(h.addr, &format!("wait job={cheap_id}")).unwrap();
    assert!(shed.starts_with(&format!("err deadline job={cheap_id} deadline_ms=1")), "{shed}");
    assert!(shed.contains("queue_ms="), "{shed}");

    let done = request(h.addr, &format!("wait job={big_id} timeout_ms=600000")).unwrap();
    assert!(done.starts_with("ok method="), "{done}");
    assert_eq!(h.state.admission.used(), 0);
    let stats = request(h.addr, "stats").unwrap();
    assert!(stats.contains(" shed=1 "), "{stats}");
    h.shutdown();
}

#[test]
fn wait_timeout_still_fires_from_the_timer_wheel() {
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    let big = request(h.addr, "submit dataset=blobs_20000_8_5 k=5 seed=3").unwrap();
    let big_id = field(&big, "job");
    assert_eq!(poll_until_past_queued(h.addr, &big_id), "running");
    let queued = request(h.addr, "submit dataset=blobs_20000_8_5 k=5 seed=5").unwrap();
    let queued_id = field(&queued, "job");

    // the timeout elapses first: the v5 timed_out=1 reply, unchanged
    // (state is queued unless the blocker finished under 30 ms)
    let t = request(h.addr, &format!("wait job={queued_id} timeout_ms=30")).unwrap();
    assert!(t.starts_with(&format!("ok job={queued_id} state=")), "{t}");
    assert!(t.contains(" timed_out=1 "), "{t}");

    let c = request(h.addr, &format!("cancel job={queued_id}")).unwrap();
    assert!(c.starts_with(&format!("ok job={queued_id}")), "{c}");
    let fin = request(h.addr, &format!("wait job={queued_id} timeout_ms=600000")).unwrap();
    assert!(fin.starts_with("err cancelled") || fin.starts_with("ok method="), "{fin}");
    let done = request(h.addr, &format!("wait job={big_id} timeout_ms=600000")).unwrap();
    assert!(done.starts_with("ok method="), "{done}");
    h.shutdown();
}

#[test]
fn conn_cap_rejects_excess_connections() {
    let h = serve(ServerConfig { workers: 1, conn_cap: 2, ..Default::default() }).unwrap();
    let a = connect(h.addr);
    let b = connect(h.addr);
    // the third connection is rejected at accept, before any request
    let (_, mut rejected) = connect(h.addr);
    assert_eq!(read_reply(&mut rejected), "err queue full");
    // admitted connections keep working
    let (mut s, mut r) = (a.0, a.1);
    writeln!(s, "ping").unwrap();
    assert!(read_reply(&mut r).starts_with("pong"));
    drop((s, r));
    drop(b);
    // freed slots are reusable (poll until the loop observes the EOFs)
    for attempt in 0..2000 {
        let (mut s, mut r) = connect(h.addr);
        writeln!(s, "ping").unwrap();
        if read_reply(&mut r).starts_with("pong") {
            break;
        }
        assert!(attempt < 1999, "slot never freed after client disconnect");
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    h.shutdown();
}

#[test]
fn stats_reports_connection_telemetry_and_reset_keeps_gauges() {
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    // a persistent pipelining connection bumps the pipelined counter
    let (mut stream, mut reader) = connect(h.addr);
    stream.write_all(b"ping\nping\nping\n").unwrap();
    for _ in 0..3 {
        assert!(read_reply(&mut reader).starts_with("pong"));
    }
    let stats = request(h.addr, "stats").unwrap();
    assert!(field(&stats, "conns").parse::<u64>().unwrap() >= 1, "{stats}");
    assert!(field(&stats, "pipelined").parse::<u64>().unwrap() >= 2, "{stats}");

    // a resolved waiter leaves the waiters gauge at zero and records at
    // least one self-pipe wakeup
    let sub = request(h.addr, "submit dataset=blobs_300_4_3 k=3 seed=1").unwrap();
    let id = field(&sub, "job");
    let done = request(h.addr, &format!("wait job={id} timeout_ms=60000")).unwrap();
    assert!(done.starts_with("ok method="), "{done}");
    let stats = request(h.addr, "stats").unwrap();
    assert_eq!(field(&stats, "waiters"), "0", "{stats}");
    assert!(field(&stats, "wakeups").parse::<u64>().unwrap() >= 1, "{stats}");

    // reset re-bases the counters but must not zero the live gauges
    assert!(request(h.addr, "stats reset").unwrap().starts_with("ok"));
    let stats = request(h.addr, "stats").unwrap();
    assert!(field(&stats, "conns").parse::<u64>().unwrap() >= 1, "gauge survives: {stats}");
    assert_eq!(field(&stats, "pipelined"), "0", "counter re-based: {stats}");
    assert_eq!(field(&stats, "wakeups"), "0", "counter re-based: {stats}");
    drop((stream, reader));
    h.shutdown();
}

#[test]
fn clara_cancel_releases_its_admission_permit_over_tcp() {
    // ROADMAP 5b (CLARA half): the spec's cancel token reaches the
    // subsample loop, so a running FasterCLARA job cancels between reps
    // and its permit returns to the admission budget
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    let sub =
        request(h.addr, "submit dataset=blobs_20000_8_5 k=5 seed=3 method=FasterCLARA-50")
            .unwrap();
    assert!(sub.starts_with("ok job="), "{sub}");
    let id = field(&sub, "job");
    assert_eq!(poll_until_past_queued(h.addr, &id), "running");
    let c = request(h.addr, &format!("cancel job={id}")).unwrap();
    assert!(
        c.contains("cancel=requested") || c.contains("state=done") || c.contains("state=cancelled"),
        "{c}"
    );
    let fin = request(h.addr, &format!("wait job={id} timeout_ms=600000")).unwrap();
    assert!(
        fin.starts_with(&format!("err cancelled job={id}")) || fin.starts_with("ok method="),
        "cancelled between reps or finished, nothing else: {fin}"
    );
    assert_eq!(h.state.admission.used(), 0, "terminal job must hold no budget");
    h.shutdown();
}

#[test]
fn v1_to_v7_replies_stay_byte_compatible_over_the_event_loop() {
    let h = serve(ServerConfig { workers: 2, ..Default::default() }).unwrap();
    // the historical request forms, all answered over one pipelined
    // connection — the strongest version of the field-order walk
    let forms = [
        "cluster dataset=blobs_300_4_3 k=3 seed=5 sampler=unif strategy=steepest", // v1
        "cluster dataset=blobs_300_4_3 k=3 seed=5 method=FasterCLARA-5",           // v2
        "cluster dataset=blobs_300_4_3 k=3 seed=5 metric=l2 scale_features=minmax", // v3
        "cluster dataset=blobs_400_4_3 k=4 seed=2 threads=2",                      // v4
        "cluster dataset=blobs_300_4_3 k=3 seed=5 profile=exact",                  // v7
    ];
    let (mut stream, mut reader) = connect(h.addr);
    for f in &forms {
        writeln!(stream, "{f}").unwrap();
    }
    for name in ["v1", "v2", "v3", "v4", "v7"] {
        let r = read_reply(&mut reader);
        assert!(r.starts_with("ok method="), "{name}: {r}");
        let mut pos = 0;
        for f in [
            "ok method=", " cache=", " medoids=", " objective=", " seconds=", " dissim=",
            " swaps=", " source=", " cost=", " inertia=", " profile=", " queue_ms=",
            " served_ms=",
        ] {
            let at = r[pos..]
                .find(f)
                .unwrap_or_else(|| panic!("{name}: {f:?} missing/misordered in {r:?}"));
            pos += at + f.len();
        }
    }

    // the v5 handle verbs and v6 serving verbs, same connection
    writeln!(stream, "submit dataset=blobs_300_4_3 k=3 seed=7").unwrap();
    let sub = read_reply(&mut reader);
    assert!(sub.starts_with("ok job=j1 cost="), "{sub}");
    writeln!(stream, "wait job=j1 timeout_ms=60000").unwrap();
    let done = read_reply(&mut reader);
    assert!(done.starts_with("ok method=OneBatch-nniw cache="), "{done}");
    writeln!(stream, "poll job=j1").unwrap();
    let polled = read_reply(&mut reader);
    assert!(polled.starts_with("ok job=j1 state=done method=OneBatch-nniw"), "{polled}");
    writeln!(stream, "promote job=j1 name=prod").unwrap();
    let p = read_reply(&mut reader);
    assert!(p.starts_with("ok model=prod job=j1 k=3 dim=4 metric=l1 inertia="), "{p}");
    writeln!(stream, "assign model=prod point=0,0,0,0 point=5,5,5,5").unwrap();
    let a = read_reply(&mut reader);
    assert!(a.starts_with("ok model=prod n=2 labels="), "{a}");
    assert_eq!(field(&a, "labels").split(',').count(), 2, "{a}");
    assert_eq!(field(&a, "dists").split(',').count(), 2, "{a}");
    writeln!(stream, "models").unwrap();
    let m = read_reply(&mut reader);
    assert!(m.starts_with("ok count=1 cap=32 promoted=1 evicted=0 model.prod.job=j1"), "{m}");
    writeln!(stream, "evict model=prod").unwrap();
    let e = read_reply(&mut reader);
    assert!(e.starts_with("ok evicted model=prod"), "{e}");
    writeln!(stream, "jobs").unwrap();
    let jobs = read_reply(&mut reader);
    assert!(jobs.starts_with("ok queued=0 running=0 retained="), "{jobs}");
    h.shutdown();
}

/// A pipelined `sleep` burst beyond `queue_cap` is rejected with the v4
/// error while the admitted sleeps resolve from the timer wheel — the
/// burst-backpressure contract without a single held thread.
#[test]
fn sleep_slots_backpressure_within_one_connection() {
    let h = serve(ServerConfig { workers: 1, queue_cap: 2, ..Default::default() }).unwrap();
    let (mut stream, mut reader) = connect(h.addr);
    for _ in 0..5 {
        writeln!(stream, "sleep ms=200").unwrap();
    }
    let replies: Vec<String> = (0..5).map(|_| read_reply(&mut reader)).collect();
    let served = replies.iter().filter(|r| r.starts_with("ok slept_ms=200")).count();
    let rejected = replies.iter().filter(|r| r.starts_with("err queue full")).count();
    assert_eq!(served + rejected, 5, "{replies:?}");
    assert_eq!(served, 2, "exactly queue_cap sleeps admitted: {replies:?}");
    h.shutdown();
}

/// Dropping a connection mid-`wait` must not leak its waiter gauge
/// entry.
#[test]
fn disconnected_waiter_releases_its_gauge_slot() {
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    let big = request(h.addr, "submit dataset=blobs_20000_8_5 k=5 seed=3").unwrap();
    let big_id = field(&big, "job");
    assert_eq!(poll_until_past_queued(h.addr, &big_id), "running");
    let queued = request(h.addr, "submit dataset=blobs_20000_8_5 k=5 seed=6").unwrap();
    let queued_id = field(&queued, "job");

    let (mut stream, _reader) = connect(h.addr);
    writeln!(stream, "wait job={queued_id} timeout_ms=600000").unwrap();
    // confirm the park landed, then vanish without reading the reply
    for _ in 0..2000 {
        if field(&request(h.addr, "stats").unwrap(), "waiters") == "1" {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(field(&request(h.addr, "stats").unwrap(), "waiters"), "1");
    drop((stream, _reader));
    for _ in 0..2000 {
        if field(&request(h.addr, "stats").unwrap(), "waiters") == "0" {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(field(&request(h.addr, "stats").unwrap(), "waiters"), "0", "gauge leaked");

    let c = request(h.addr, &format!("cancel job={queued_id}")).unwrap();
    assert!(c.starts_with(&format!("ok job={queued_id}")), "{c}");
    let fin = request(h.addr, &format!("wait job={queued_id} timeout_ms=600000")).unwrap();
    assert!(fin.starts_with("err cancelled") || fin.starts_with("ok method="), "{fin}");
    let done = request(h.addr, &format!("wait job={big_id} timeout_ms=600000")).unwrap();
    assert!(done.starts_with("ok method="), "{done}");
    h.shutdown();
}
