//! Cross-module integration tests (native path; XLA agreement lives in
//! xla_native_agreement.rs).

use obpam::backend::NativeBackend;
use obpam::baselines;
use obpam::coordinator::{one_batch_pam, OneBatchConfig, SamplerKind};
use obpam::data::synth;
use obpam::dissim::{DissimCounter, Metric};
use obpam::eval;
use obpam::harness::methods::MethodSpec;
use obpam::rng::Rng;

/// End-to-end: on well-separated planted clusters, OneBatchPAM recovers
/// one medoid per cluster (checked by cluster-purity of the medoids).
#[test]
fn recovers_planted_clusters() {
    let mut rng = Rng::new(42);
    // 4 tight clusters far apart: centers at distance >> spread
    let n_per = 100;
    let mut data = Vec::new();
    for c in 0..4 {
        for _ in 0..n_per {
            let cx = (c as f32) * 50.0;
            data.push(cx + rng.normal() as f32 * 0.5);
            data.push(cx + rng.normal() as f32 * 0.5);
        }
    }
    let x = obpam::linalg::Matrix::from_vec(4 * n_per, 2, data);
    let backend = NativeBackend::new(Metric::L1);
    let cfg = OneBatchConfig { k: 4, sampler: SamplerKind::Unif, m: Some(80), seed: 1, ..Default::default() };
    let r = one_batch_pam(&x, &cfg, &backend).unwrap();
    // each medoid must come from a distinct planted cluster
    let clusters: std::collections::HashSet<usize> =
        r.medoids.iter().map(|&m| m / n_per).collect();
    assert_eq!(clusters.len(), 4, "medoids {:?} miss a cluster", r.medoids);
}

/// OneBatchPAM objective tracks FasterPAM within a small factor on every
/// small-scale synthetic dataset (the paper's central claim, scaled).
#[test]
fn onebatch_tracks_fasterpam_within_10pct() {
    for ds in ["abalone", "drybean"] {
        let data = synth::generate(ds, 0.05, 3);
        let x = &data.x;
        let k = 5;
        let eval_d = DissimCounter::new(Metric::L1);

        let b1 = NativeBackend::new(Metric::L1);
        let fp = baselines::faster_pam(x, k, 50, 4, &b1).unwrap();
        let fp_obj = eval::objective(x, &fp.medoids, &eval_d);

        // the paper-default m = 100 log(kn) saturates at n for datasets
        // this small; force a genuinely sub-n batch to test the trade-off
        let b2 = NativeBackend::new(Metric::L1);
        let cfg = OneBatchConfig {
            k,
            sampler: SamplerKind::Nniw,
            m: Some(x.rows / 4),
            seed: 4,
            ..Default::default()
        };
        let ob = one_batch_pam(x, &cfg, &b2).unwrap();
        let ob_obj = eval::objective(x, &ob.medoids, &eval_d);

        assert!(
            ob_obj <= fp_obj * 1.10,
            "{ds}: OneBatch {ob_obj} vs FasterPAM {fp_obj} (>10% off)"
        );
        // and it must do far less work
        assert!(
            ob.stats.dissim_count * 2 <= fp.stats.dissim_count,
            "{ds}: expected >=2x dissim reduction, got {} vs {}",
            ob.stats.dissim_count,
            fp.stats.dissim_count
        );
    }
}

/// The method ordering of Table 3 (objective): FasterPAM <= OneBatch <=
/// CLARA-ish <= k-means++-ish <= Random, with slack for stochasticity.
#[test]
fn table3_quality_ordering_holds() {
    let data = synth::generate("mapping", 0.05, 9);
    let x = &data.x;
    let k = 8;
    let eval_d = DissimCounter::new(Metric::L1);
    let obj_of = |m: &MethodSpec| -> f64 {
        let out = m.run(x, k, Metric::L1, 17).unwrap();
        eval::objective(x, &out.medoids, &eval_d)
    };
    let fp = obj_of(&MethodSpec::FasterPam);
    let ob = obj_of(&MethodSpec::OneBatch {
        sampler: SamplerKind::Nniw,
        strategy: obpam::coordinator::onebatch::SwapStrategy::Eager,
    });
    let km = obj_of(&MethodSpec::KMeansPp);
    let rnd = obj_of(&MethodSpec::Random);
    assert!(fp <= ob * 1.05, "FasterPAM {fp} should be <= OneBatch {ob}");
    assert!(ob < km, "OneBatch {ob} should beat k-means++ {km}");
    assert!(km < rnd * 1.2, "k-means++ {km} should roughly beat Random {rnd}");
    assert!(ob < rnd, "OneBatch must beat Random");
}

/// Every algorithm exposed through the harness produces valid medoids on
/// every synthetic dataset family (tiny scale).
#[test]
fn all_methods_all_datasets_smoke() {
    for &(ds, _, _, _) in synth::CATALOGUE {
        let data = synth::generate(ds, 0.002, 1);
        if data.n() < 40 {
            continue;
        }
        for m in [
            MethodSpec::Random,
            MethodSpec::KMeansPp,
            MethodSpec::OneBatch {
                sampler: SamplerKind::Unif,
                strategy: obpam::coordinator::onebatch::SwapStrategy::Eager,
            },
        ] {
            let out = m.run(&data.x, 3, Metric::L1, 2).unwrap();
            assert_eq!(out.medoids.len(), 3, "{ds}/{}", m.label());
        }
    }
}

/// Server round-trip under concurrent load, including backpressure.
#[test]
fn server_concurrent_requests() {
    let h = obpam::server::serve(obpam::server::ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        queue_cap: 32,
        ..Default::default()
    })
    .unwrap();
    let addr = h.addr;
    let threads: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                obpam::server::request(
                    addr,
                    &format!("cluster dataset=blobs_300_4_3 k=3 seed={i}"),
                )
                .unwrap()
            })
        })
        .collect();
    for t in threads {
        let reply = t.join().unwrap();
        assert!(reply.starts_with("ok "), "{reply}");
    }
    h.shutdown();
}

/// Property: across samplers and seeds, est_objective is finite, medoids
/// valid, and the batch estimate is within 3x of the exact objective
/// (it is an estimator, not an oracle).
#[test]
fn property_estimates_sane_across_instances() {
    obpam::proptest::run_cases(25, |rng| {
        let n = 80 + rng.below(120);
        let p = 2 + rng.below(6);
        let k = 2 + rng.below(4);
        let kc = 2 + rng.below(4);
        let x = synth::gen_gaussian_mixture(rng, n, p, kc, 0.2, 1.5);
        let sampler = SamplerKind::all()[rng.below(4)];
        let backend = NativeBackend::new(Metric::L1);
        let cfg = OneBatchConfig {
            k,
            sampler,
            m: Some((20 + rng.below(40)).min(n)),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let r = one_batch_pam(&x, &cfg, &backend).unwrap();
        r.validate(n, k);
        assert!(r.est_objective.is_finite() && r.est_objective >= 0.0);
        let exact = eval::objective(&x, &r.medoids, &DissimCounter::new(Metric::L1));
        assert!(
            r.est_objective < exact * 3.0 + 1.0 && exact < r.est_objective * 3.0 + 1.0,
            "estimate {} vs exact {exact} too far apart",
            r.est_objective
        );
    });
}

/// Property: FasterPAM (m = n, unweighted) est_objective equals the exact
/// full objective, and never increases across runs with more passes.
#[test]
fn property_fasterpam_exactness() {
    obpam::proptest::run_cases(15, |rng| {
        let n = 50 + rng.below(80);
        let k = 2 + rng.below(3);
        let x = synth::gen_gaussian_mixture(rng, n, 3, 3, 0.3, 1.0);
        let backend = NativeBackend::new(Metric::L1);
        let r = baselines::faster_pam(&x, k, 30, rng.next_u64(), &backend).unwrap();
        let exact = eval::objective(&x, &r.medoids, &DissimCounter::new(Metric::L1));
        assert!(
            (exact - r.est_objective).abs() < 1e-3 * exact.max(1.0),
            "est {} != exact {exact}",
            r.est_objective
        );
    });
}

/// CLI dataset generators cover the paper's Table 2 at full configured
/// shape (p always exact, n scaled).
#[test]
fn catalogue_shapes_match_table2() {
    for &(name, n_full, p, _) in synth::CATALOGUE {
        let d = synth::generate(name, 0.001, 0);
        assert_eq!(d.p(), p);
        assert!(d.n() >= 64 && d.n() <= n_full);
    }
}
