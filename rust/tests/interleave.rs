//! Deterministic interleaving harness for the named concurrency races.
//!
//! Real races are driven by the scheduler; CI cannot enumerate kernel
//! schedules.  But every shared structure in this tree is a monitor —
//! all state transitions happen under one mutex — so the reachable
//! interleavings of N operations are exactly the N! orders in which
//! their critical sections acquire the lock.  This suite enumerates
//! those orders explicitly (Heap's algorithm over small op lists) and
//! asserts the invariants that must hold on *every* schedule:
//!
//! * **pool publish/claim/quiesce** — permuted region shapes, nested
//!   regions, and a mid-schedule task panic on one reused [`Pool`];
//!   every region retires, task coverage is exact, and (debug builds)
//!   the published/retired counters balance.
//! * **permit reserve-vs-release** — permuted admit / drop / reprice
//!   schedules on an [`AdmissionBudget`]; the budget returns to zero
//!   and (debug builds) reserved units equal released units.
//! * **registry cancel-vs-complete** — permuted drain / cancel / poll
//!   schedules; whichever of drain or cancel locks first wins, the
//!   loser observes a terminal state, the conservation identity
//!   `submitted == queued + running + terminals` holds after every
//!   step, and completed runs yield bit-identical medoids.
//!   (The mid-run cooperative-cancel half of this race — token flip
//!   while the solver is inside a batch — is exercised end-to-end by
//!   the running-job cancel test in `jobs_api`.)
//! * **wait-vs-deadline** — a queued job whose deadline passed is shed
//!   by whichever lazy-expiry observer (poll / cancel / wait / gauges)
//!   reaches it first, exactly once, on every observer order.
//! * **cache in-flight marker** — failed loads clear the in-flight
//!   marker on every schedule (a leaked marker would hang the next
//!   request for the same key), including concurrent duplicates.
//!   (The panic path is guarded by the same `UnmarkOnDrop` guard the
//!   error path uses.)
//!
//! CI runs this suite under the `OBPAM_THREADS` {1, 4} matrix; the env
//! width joins the pool widths compared below.

use obpam::runtime::Pool;
use obpam::server::{handle_line, AdmissionBudget, ServerConfig, ServerState};

/// All permutations of `0..n`, via Heap's algorithm (n! schedules).
fn permutations(n: usize) -> Vec<Vec<usize>> {
    let mut items: Vec<usize> = (0..n).collect();
    let mut out = vec![items.clone()];
    let mut c = vec![0usize; n];
    let mut i = 0;
    while i < n {
        if c[i] < i {
            if i % 2 == 0 {
                items.swap(0, i);
            } else {
                items.swap(c[i], i);
            }
            out.push(items.clone());
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }
    out
}

fn fresh() -> ServerState {
    ServerState::new(&ServerConfig::default())
}

/// The value of `key` (e.g. `"medoids="`) in a wire reply.
fn field<'a>(reply: &'a str, key: &str) -> &'a str {
    reply
        .split(key)
        .nth(1)
        .and_then(|t| t.split_whitespace().next())
        .unwrap_or_else(|| panic!("no {key} in {reply:?}"))
}

/// The conservation identity every schedule must preserve: each
/// submitted job is in exactly one place.
fn assert_conservation(st: &ServerState, ctx: &str) {
    let g = st.jobs.gauges();
    let c = st.jobs.counters();
    let terminal = c.done() + c.failed() + c.cancelled() + c.expired();
    assert_eq!(
        c.submitted(),
        g.queued as u64 + g.running as u64 + terminal,
        "conservation broken ({ctx}): gauges={g:?}"
    );
}

fn env_width() -> Option<usize> {
    std::env::var("OBPAM_THREADS").ok().and_then(|s| s.parse().ok())
}

// ---------------------------------------------------------------------------
// race: pool publish / claim / quiesce
// ---------------------------------------------------------------------------

#[test]
fn pool_regions_survive_permuted_shapes_nesting_and_panics() {
    let mut widths = vec![1usize, 2, 4];
    if let Some(w) = env_width() {
        if !widths.contains(&w) {
            widths.push(w);
        }
    }
    for &w in &widths {
        let pool = if w == 1 { Pool::serial() } else { Pool::new(w) };

        // permuted region shapes on one reused pool instance: every
        // publish is claimed exactly and retired before the next
        let shapes = [1usize, 3, 8, 17];
        for perm in permutations(shapes.len()) {
            for &si in &perm {
                let n = shapes[si];
                let parts = pool.map_ranges(n, |r| r.len());
                assert_eq!(parts.iter().sum::<usize>(), n, "width {w}, schedule {perm:?}");
            }
        }

        // nested region: the inner one finds the region slot busy and
        // runs inline instead of deadlocking on the parked workers
        let outer = pool.map_ranges(4, |r| {
            let inner: usize = pool.map_ranges(6, |q| q.len()).into_iter().sum();
            (r.len(), inner)
        });
        assert_eq!(outer.iter().map(|&(l, _)| l).sum::<usize>(), 4, "width {w}");
        assert!(outer.iter().all(|&(_, inner)| inner == 6), "width {w}: {outer:?}");

        // a task panic mid-schedule unwinds to the caller, quiesces the
        // region, and leaves the pool (and its poisoned region mutex)
        // fully usable for the rest of the schedule
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map_ranges(5, |r| {
                if r.start == 0 {
                    panic!("task boom");
                }
                r.len()
            })
        }));
        assert!(boom.is_err(), "width {w}: the panic must reach the caller");
        let parts = pool.map_ranges(9, |r| r.len());
        assert_eq!(parts.iter().sum::<usize>(), 9, "width {w}: pool must survive a panic");

        #[cfg(debug_assertions)]
        {
            let (published, retired) = pool.debug_region_flow();
            assert_eq!(
                published, retired,
                "width {w}: every published region must retire, panics included"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// race: admission permit reserve vs release
// ---------------------------------------------------------------------------

#[test]
fn permit_schedules_always_balance_the_budget_to_zero() {
    // ops: admit 40 into slot 0, admit 70 into slot 1 (fits only via
    // the idle exception — order decides), drop slot 0, reprice slot 1
    // down to 20.  Depending on order some ops fail; the invariant is
    // indifferent: whatever was reserved is released.
    for perm in permutations(4) {
        let budget = AdmissionBudget::new(100);
        let mut slots: [Option<obpam::server::AdmissionPermit<'_>>; 2] = [None, None];
        for &op in &perm {
            match op {
                0 => {
                    if let Ok(p) = budget.try_admit(40) {
                        slots[0] = Some(p);
                    }
                }
                1 => {
                    if let Ok(p) = budget.try_admit(70) {
                        slots[1] = Some(p);
                    }
                }
                2 => slots[0] = None, // drop releases
                3 => {
                    if let Some(p) = slots[1].as_mut() {
                        let _ = p.reprice(20);
                    }
                }
                _ => unreachable!(),
            }
            let held: u64 = slots.iter().flatten().map(|p| p.units()).sum();
            assert_eq!(budget.used(), held, "schedule {perm:?}: used must track live permits");
        }
        drop(slots);
        assert_eq!(budget.used(), 0, "schedule {perm:?} must balance to zero");
        #[cfg(debug_assertions)]
        {
            let (reserved, released) = budget.debug_units_flow();
            assert_eq!(reserved, released, "schedule {perm:?}: unit flow must balance");
        }
    }
}

// ---------------------------------------------------------------------------
// race: registry cancel vs complete
// ---------------------------------------------------------------------------

#[test]
fn cancel_vs_complete_is_decided_by_lock_order_and_stays_terminal_once() {
    // ops: 0 = drain_one (worker pickup + completion), 1 = cancel,
    // 2 = poll (an innocent observer anywhere in the schedule)
    let mut done_medoids: Vec<String> = Vec::new();
    for perm in permutations(3) {
        let st = fresh();
        let r = handle_line(&st, "submit dataset=blobs_300_4_3 k=3 seed=7");
        assert!(r.starts_with("ok job=j1 "), "{r}");
        for &op in &perm {
            match op {
                0 => {
                    let _ = st.drain_one();
                }
                1 => {
                    let c = handle_line(&st, "cancel job=j1");
                    assert!(c.starts_with("ok job=j1 state="), "{c}");
                }
                2 => {
                    let p = handle_line(&st, "poll job=j1");
                    assert!(p.starts_with("ok job=j1 "), "{p}");
                }
                _ => unreachable!(),
            }
            assert_conservation(&st, &format!("schedule {perm:?}"));
        }
        // whoever locked the registry first won the race — and the
        // outcome is a pure function of the schedule
        let p = handle_line(&st, "poll job=j1");
        let drain_first = perm.iter().position(|&o| o == 0).unwrap()
            < perm.iter().position(|&o| o == 1).unwrap();
        if drain_first {
            assert!(p.starts_with("ok job=j1 state=done "), "schedule {perm:?}: {p}");
            done_medoids.push(field(&p, "medoids=").to_string());
        } else {
            assert!(p.starts_with("ok job=j1 state=cancelled"), "schedule {perm:?}: {p}");
            // the losing drain found an empty queue
            assert!(!st.drain_one(), "schedule {perm:?}: cancelled job must leave the queue");
        }
        // terminal exactly once, permit released either way
        let c = st.jobs.counters();
        assert_eq!(c.done() + c.cancelled(), 1, "schedule {perm:?}");
        assert_eq!(st.admission.used(), 0, "schedule {perm:?}: permit must be released");
        #[cfg(debug_assertions)]
        {
            let (reserved, released) = st.admission.debug_units_flow();
            assert_eq!(reserved, released, "schedule {perm:?}: unit flow must balance");
        }
    }
    // every schedule that completed the job computed the same medoids
    assert!(!done_medoids.is_empty());
    assert!(
        done_medoids.iter().all(|m| m == &done_medoids[0]),
        "medoids must be bit-identical across schedules: {done_medoids:?}"
    );
}

// ---------------------------------------------------------------------------
// race: wait vs deadline
// ---------------------------------------------------------------------------

#[test]
fn deadline_shed_happens_exactly_once_under_any_observer_order() {
    // every observer triggers lazy expiry; permute which one gets there
    // first.  ops: 0 = poll, 1 = cancel, 2 = gauges, 3 = bounded wait
    for perm in permutations(4) {
        let st = fresh();
        let r = handle_line(&st, "submit dataset=blobs_300_4_3 k=3 seed=1 deadline_ms=1");
        assert!(r.starts_with("ok job=j1 "), "{r}");
        // no workers: the job sits queued while its deadline passes
        std::thread::sleep(std::time::Duration::from_millis(5));
        for &op in &perm {
            match op {
                0 => {
                    let _ = handle_line(&st, "poll job=j1");
                }
                1 => {
                    let _ = handle_line(&st, "cancel job=j1");
                }
                2 => {
                    let _ = st.jobs.gauges();
                }
                3 => {
                    let _ = handle_line(&st, "wait job=j1 timeout_ms=1");
                }
                _ => unreachable!(),
            }
            assert_conservation(&st, &format!("schedule {perm:?}"));
        }
        let p = handle_line(&st, "poll job=j1");
        assert!(p.starts_with("ok job=j1 state=expired "), "schedule {perm:?}: {p}");
        let c = st.jobs.counters();
        assert_eq!(c.expired(), 1, "schedule {perm:?}: shed exactly once");
        assert_eq!(c.shed(), 1, "schedule {perm:?}");
        assert_eq!(c.cancelled(), 0, "schedule {perm:?}: expiry wins over a late cancel");
        assert_eq!(st.admission.used(), 0, "schedule {perm:?}: shed must release the permit");
        assert!(!st.drain_one(), "schedule {perm:?}: a shed job must leave the queue");
    }
}

// ---------------------------------------------------------------------------
// race: cache in-flight marker
// ---------------------------------------------------------------------------

#[test]
fn failed_loads_clear_the_inflight_marker_on_every_schedule() {
    // ops: two failing loads of the same key and a succeeding load of
    // another.  If the error path leaked the in-flight marker, the
    // second request for the failing key would block forever.
    for perm in permutations(3) {
        let st = fresh();
        for &op in &perm {
            match op {
                0 | 1 => {
                    let r = handle_line(&st, "cluster dataset=doesnotexist k=3");
                    assert!(r.starts_with("err"), "schedule {perm:?}: {r}");
                }
                2 => {
                    let r = handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=1");
                    assert!(r.starts_with("ok "), "schedule {perm:?}: {r}");
                }
                _ => unreachable!(),
            }
        }
        // the failing key errs cleanly (not hangs) one more time
        let r = handle_line(&st, "cluster dataset=doesnotexist k=3");
        assert!(r.starts_with("err"), "schedule {perm:?}: {r}");
    }

    // concurrent duplicates: every loser of the in-flight race must be
    // woken and handed the error, and no marker may leak
    let st = std::sync::Arc::new(fresh());
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let st = st.clone();
            std::thread::spawn(move || handle_line(&st, "cluster dataset=doesnotexist k=3"))
        })
        .collect();
    for h in handles {
        assert!(h.join().unwrap().starts_with("err"));
    }
    let r = handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=1");
    assert!(r.starts_with("ok "), "{r}");
}

// ---------------------------------------------------------------------------
// bit-identical medoids across submit schedules
// ---------------------------------------------------------------------------

#[test]
fn submit_order_schedules_yield_bit_identical_medoids_per_seed() {
    let seeds = [1u64, 2, 3];
    let mut reference: Option<Vec<String>> = None;
    for perm in permutations(seeds.len()) {
        let st = fresh();
        // submit the same three jobs in permuted order...
        let mut id_of_seed = vec![0usize; seeds.len()];
        for (submit_idx, &si) in perm.iter().enumerate() {
            let line = format!("submit dataset=blobs_300_4_3 k=3 seed={}", seeds[si]);
            let r = handle_line(&st, &line);
            assert!(r.starts_with("ok job=j"), "{r}");
            id_of_seed[si] = submit_idx + 1; // handles are monotonic
        }
        // ...drain them all deterministically...
        let mut drained = 0;
        while st.drain_one() {
            drained += 1;
        }
        assert_eq!(drained, seeds.len(), "schedule {perm:?}");
        // ...and the medoids for a given seed must not depend on the
        // schedule the jobs arrived (or ran) in
        let got: Vec<String> = id_of_seed
            .iter()
            .map(|&id| {
                let p = handle_line(&st, &format!("poll job=j{id}"));
                assert!(p.starts_with(&format!("ok job=j{id} state=done ")), "{p}");
                field(&p, "medoids=").to_string()
            })
            .collect();
        match &reference {
            None => reference = Some(got),
            Some(want) => assert_eq!(want, &got, "schedule {perm:?}"),
        }
        assert_eq!(st.admission.used(), 0, "schedule {perm:?}");
        assert_conservation(&st, &format!("schedule {perm:?}"));
    }
}
