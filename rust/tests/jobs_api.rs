//! Protocol v5 asynchronous job handles from the outside: the
//! `submit` / `poll` / `wait` / `cancel` lifecycle over real TCP,
//! v4 `cluster` byte-compatibility through the v5 job registry,
//! deadline sheds of queued jobs, finished-job retention eviction, and
//! a concurrent submit burst against a tight admission budget.
//!
//! Deterministic lifecycle corners (queued-forever, shed-while-queued,
//! LRU eviction) run against a *workerless* `ServerState`: without
//! workers a submitted job stays queued indefinitely, so every queued
//! transition can be asserted without racing a solver.

use obpam::server::{handle_line, request, serve, ServerConfig, ServerState};
use obpam::solver::MethodSpec;

fn workerless() -> ServerState {
    ServerState::new(&ServerConfig::default())
}

/// Extract `key=<token>` from a reply line.
fn field(reply: &str, key: &str) -> String {
    reply
        .split_whitespace()
        .find_map(|t| t.strip_prefix(&format!("{key}=")))
        .unwrap_or_else(|| panic!("no {key}= in {reply:?}"))
        .to_string()
}

/// Poll `job` on `addr` until its state leaves `queued` (worker pickup)
/// or the attempts run out; returns the last observed state.
fn poll_until_past_queued(addr: std::net::SocketAddr, job: &str) -> String {
    for _ in 0..20_000 {
        let r = request(addr, &format!("poll job={job}")).unwrap();
        let state = field(&r, "state");
        if state != "queued" {
            return state;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    panic!("job {job} never left the queue");
}

#[test]
fn submit_poll_wait_lifecycle_over_tcp() {
    let h = serve(ServerConfig { workers: 2, ..Default::default() }).unwrap();
    let sub = request(h.addr, "submit dataset=blobs_300_4_3 k=3 seed=7").unwrap();
    assert!(sub.starts_with("ok job=j1 cost="), "{sub}");
    let cost: u64 = field(&sub, "cost").parse().unwrap();
    assert_eq!(cost, MethodSpec::default().cost(300, 3, None).units, "{sub}");
    // submit replies ride the standard connection trailer
    assert!(sub.contains(" queue_ms="), "{sub}");
    assert!(sub.contains(" served_ms="), "{sub}");

    // wait returns the stored cluster reply verbatim (plus trailer)
    let done = request(h.addr, "wait job=j1 timeout_ms=60000").unwrap();
    assert!(done.starts_with("ok method=OneBatch-nniw cache="), "{done}");
    assert!(done.contains(" medoids="), "{done}");
    assert!(done.contains(" objective="), "{done}");
    assert_eq!(field(&done, "cost").parse::<u64>().unwrap(), cost, "{done}");

    // a later connection can still read the terminal state
    let polled = request(h.addr, "poll job=j1").unwrap();
    assert!(polled.starts_with("ok job=j1 state=done method=OneBatch-nniw"), "{polled}");
    // wait on a terminal job is immediate and idempotent
    let again = request(h.addr, "wait job=j1 timeout_ms=1000").unwrap();
    assert_eq!(field(&again, "medoids"), field(&done, "medoids"));

    let jobs = request(h.addr, "jobs").unwrap();
    assert!(jobs.starts_with("ok queued=0 running=0 retained=1 submitted=1 done=1 "), "{jobs}");
    assert_eq!(h.state.admission.used(), 0, "terminal job must hold no budget");
    h.shutdown();
}

#[test]
fn cluster_lines_are_byte_compatible_with_submit_plus_wait() {
    // every pre-v5 request form must keep its reply shape through the
    // v5 registry, and submit+wait must reproduce the same solve
    let h = serve(ServerConfig::default()).unwrap();
    for (name, keys) in [
        ("v1 legacy", "dataset=blobs_300_4_3 k=3 seed=5 sampler=unif strategy=steepest"),
        ("v2 method", "dataset=blobs_300_4_3 k=3 seed=5 method=FasterCLARA-5"),
        ("v3 metric", "dataset=blobs_300_4_3 k=3 seed=5 metric=l2 scale_features=minmax"),
        ("v4 plain", "dataset=blobs_400_4_3 k=4 seed=2 threads=2"),
    ] {
        let cluster = request(h.addr, &format!("cluster {keys}")).unwrap();
        assert!(cluster.starts_with("ok method="), "{name}: {cluster}");
        // the v4 field sequence, in order (v7 appends profile= after the
        // job fields, before the connection trailer)
        let mut pos = 0;
        for f in [
            "ok method=", " cache=", " medoids=", " objective=", " seconds=", " dissim=",
            " swaps=", " source=", " cost=", " profile=", " queue_ms=", " served_ms=",
        ] {
            let at = cluster[pos..]
                .find(f)
                .unwrap_or_else(|| panic!("{name}: {f:?} missing/misordered in {cluster:?}"));
            pos += at + f.len();
        }
        // submit + wait: same medoids, objective and cost for the spec
        let sub = request(h.addr, &format!("submit {keys}")).unwrap();
        assert!(sub.starts_with("ok job="), "{name}: {sub}");
        let id = field(&sub, "job");
        let waited = request(h.addr, &format!("wait job={id} timeout_ms=60000")).unwrap();
        for f in ["method", "medoids", "objective", "dissim", "swaps", "source", "cost"] {
            assert_eq!(field(&waited, f), field(&cluster, f), "{name}: {f} differs");
        }
    }
    h.shutdown();
}

#[test]
fn deadline_shed_of_a_queued_job_is_deterministic() {
    // no workers: the job stays queued, so the deadline must shed it
    let st = workerless();
    let sub = handle_line(&st, "submit dataset=blobs_300_4_3 k=3 seed=1 deadline_ms=1");
    assert!(sub.starts_with("ok job=j1 cost="), "{sub}");
    let cost: u64 = field(&sub, "cost").parse().unwrap();
    assert_eq!(st.admission.used(), cost, "queued job holds its permit");
    std::thread::sleep(std::time::Duration::from_millis(10));
    // lazy expiry: the next observation flips the job to expired
    let polled = handle_line(&st, "poll job=j1");
    assert!(polled.starts_with("ok job=j1 state=expired error=deadline job=j1"), "{polled}");
    assert!(polled.contains("deadline_ms=1"), "{polled}");
    assert!(polled.contains("queue_ms="), "{polled}");
    assert_eq!(st.admission.used(), 0, "shed must release the admission permit");
    // wait returns the stored shed error verbatim
    let waited = handle_line(&st, "wait job=j1 timeout_ms=50");
    assert!(waited.starts_with("err deadline job=j1 deadline_ms=1 queue_ms="), "{waited}");
    // the shed is recorded (jobs verb and stats field agree)
    let jobs = handle_line(&st, "jobs");
    assert!(jobs.contains(" expired=1 shed=1"), "{jobs}");
    let stats = handle_line(&st, "stats");
    assert!(stats.contains(" jobs.expired=1 "), "{stats}");
    assert!(stats.contains(" shed=1 "), "{stats}");

    // a deadline generous enough is not shed: the job just stays queued
    let sub = handle_line(&st, "submit dataset=blobs_300_4_3 k=3 seed=1 deadline_ms=600000");
    assert!(sub.starts_with("ok job=j2"), "{sub}");
    assert!(handle_line(&st, "poll job=j2").contains("state=queued"));
}

#[test]
fn deadline_shed_over_tcp_behind_a_busy_worker() {
    // one worker, occupied by a long job: a queued job with a 1 ms
    // deadline must be shed, and its budget must return to baseline —
    // asserted over TCP, per the acceptance criteria
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    let big = request(h.addr, "submit dataset=blobs_20000_8_5 k=5 seed=3").unwrap();
    assert!(big.starts_with("ok job="), "{big}");
    let big_id = field(&big, "job");
    assert_eq!(poll_until_past_queued(h.addr, &big_id), "running");

    let cheap = request(h.addr, "submit dataset=blobs_300_4_3 k=3 seed=1 deadline_ms=1").unwrap();
    assert!(cheap.starts_with("ok job="), "{cheap}");
    let cheap_id = field(&cheap, "job");
    // wait wakes itself at the job's deadline even though the lone
    // worker is busy elsewhere — the shed needs no worker
    let shed = request(h.addr, &format!("wait job={cheap_id} timeout_ms=60000")).unwrap();
    assert!(shed.starts_with(&format!("err deadline job={cheap_id} deadline_ms=1")), "{shed}");
    assert!(shed.contains("queue_ms="), "{shed}");

    // the big job still completes; afterwards the budget gauge is back
    // to baseline (shed + finished jobs both released their permits)
    let done = request(h.addr, &format!("wait job={big_id} timeout_ms=600000")).unwrap();
    assert!(done.starts_with("ok method="), "{done}");
    let stats = request(h.addr, "stats").unwrap();
    assert!(stats.contains(" budget_used=0 "), "{stats}");
    assert!(stats.contains(" shed=1 "), "{stats}");
    assert_eq!(h.state.admission.used(), 0);
    h.shutdown();
}

#[test]
fn finished_job_retention_evicts_least_recently_touched() {
    let st = ServerState::new(&ServerConfig { retain_cap: 2, ..Default::default() });
    for i in 1..=3 {
        assert!(handle_line(&st, "submit dataset=blobs_300_4_3 k=3").starts_with("ok job="));
        assert_eq!(
            handle_line(&st, &format!("cancel job=j{i}")),
            format!("ok job=j{i} state=cancelled")
        );
    }
    // three finished, cap two: the coldest (j1) is gone
    assert!(handle_line(&st, "poll job=j1").starts_with("err unknown job j1"));
    assert!(handle_line(&st, "poll job=j2").contains("state=cancelled"));
    assert!(handle_line(&st, "poll job=j3").contains("state=cancelled"));
    let jobs = handle_line(&st, "jobs");
    assert!(jobs.contains(" retained=2 "), "{jobs}");
    // the poll above touched j2 last -> j3 is now the LRU victim
    assert!(handle_line(&st, "poll job=j2").contains("state=cancelled"));
    assert!(handle_line(&st, "submit dataset=blobs_300_4_3 k=3").starts_with("ok job=j4"));
    assert_eq!(handle_line(&st, "cancel job=j4"), "ok job=j4 state=cancelled");
    assert!(handle_line(&st, "poll job=j3").starts_with("err unknown job j3"), "LRU evicts j3");
    assert!(handle_line(&st, "poll job=j2").contains("state=cancelled"), "touched j2 survives");
    assert_eq!(st.admission.used(), 0);
}

#[test]
fn concurrent_submit_burst_against_a_tight_budget() {
    // a budget sized for ~1.5 cheap jobs: concurrent submits either get
    // a handle or an immediate priced rejection; every admitted job
    // completes and the budget fully drains
    let cheap = MethodSpec::default().cost(300, 3, None).units;
    let h = serve(ServerConfig {
        workers: 4,
        queue_cap: 16,
        budget: cheap + cheap / 2,
        ..Default::default()
    })
    .unwrap();
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let addr = h.addr;
            std::thread::spawn(move || {
                request(addr, &format!("submit dataset=blobs_300_4_3 k=3 seed={}", i % 2)).unwrap()
            })
        })
        .collect();
    let replies: Vec<String> = handles.into_iter().map(|t| t.join().unwrap()).collect();
    let mut ids = Vec::new();
    for r in &replies {
        assert!(
            r.starts_with("ok job=") || r.starts_with("err over budget"),
            "unexpected reply: {r}"
        );
        assert!(r.contains("cost="), "every decision is priced: {r}");
        if r.starts_with("ok job=") {
            ids.push(field(r, "job"));
        }
    }
    assert!(!ids.is_empty(), "at least one submit must be admitted: {replies:?}");
    for id in &ids {
        let done = request(h.addr, &format!("wait job={id} timeout_ms=60000")).unwrap();
        assert!(done.starts_with("ok method="), "{id}: {done}");
    }
    assert_eq!(h.state.admission.used(), 0, "budget must drain when jobs finish");
    let jobs = request(h.addr, "jobs").unwrap();
    assert!(jobs.contains(&format!(" done={} ", ids.len())), "{jobs}");
    h.shutdown();
}

#[test]
fn cancel_running_job_releases_budget_over_tcp() {
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    let sub = request(h.addr, "submit dataset=blobs_20000_8_5 k=5 seed=9").unwrap();
    assert!(sub.starts_with("ok job="), "{sub}");
    let id = field(&sub, "job");
    assert_eq!(poll_until_past_queued(h.addr, &id), "running");
    let c = request(h.addr, &format!("cancel job={id}")).unwrap();
    // cancellation is cooperative: either the request landed while the
    // job was still running, or the job beat it to a terminal state
    assert!(
        c.contains("cancel=requested") || c.contains("state=done") || c.contains("state=cancelled"),
        "{c}"
    );
    let fin = request(h.addr, &format!("wait job={id} timeout_ms=600000")).unwrap();
    assert!(
        fin.starts_with(&format!("err cancelled job={id}")) || fin.starts_with("ok method="),
        "cancelled or finished, nothing else: {fin}"
    );
    assert_eq!(h.state.admission.used(), 0, "terminal job must hold no budget");
    // idempotent: cancelling a terminal job reports its state
    let again = request(h.addr, &format!("cancel job={id}")).unwrap();
    assert!(again.contains("state=cancelled") || again.contains("state=done"), "{again}");
    h.shutdown();
}

#[test]
fn submit_of_invalid_requests_fails_like_cluster() {
    let st = workerless();
    for line in [
        "submit dataset=doesnotexist-not-a-name k=1",
        "submit k=1",
        "submit method=bogus",
        "submit method=FasterPAM m=50",
        "submit dataset=file:/nope.csv?rows=50000 k=5 method=FasterPAM",
        "submit deadline_ms=0",
    ] {
        let r = handle_line(&st, line);
        assert!(r.starts_with("err"), "{line:?} -> {r}");
    }
    let g = st.jobs.gauges();
    assert_eq!((g.queued, g.running, g.retained), (0, 0, 0), "nothing enqueued");
    assert_eq!(st.admission.used(), 0);
}
