//! Protocol v6 model serving from the outside: the `submit` / `wait` /
//! `promote` / `assign` / `evict` lifecycle over real TCP, assignment
//! determinism against the offline `backend::assign` path **with the
//! dataset cache cleared** (the registry's whole point: serving needs
//! no dataset resident), registry LRU eviction, mismatch errors, the
//! trailing-field-only v5 byte-compatibility guarantee, and the
//! FasterPAM cooperative-cancellation permit release (ROADMAP 5b).
//!
//! Deterministic registry corners run against a *workerless*
//! `ServerState` driven by `drain_one()`, so every promote precondition
//! can be asserted without racing a solver.

use obpam::backend::{self, NativeBackend};
use obpam::data::DataSource;
use obpam::dissim::Metric;
use obpam::server::{handle_line, request, serve, ServerConfig, ServerState};

/// Extract `key=<token>` from a reply line.
fn field(reply: &str, key: &str) -> String {
    reply
        .split_whitespace()
        .find_map(|t| t.strip_prefix(&format!("{key}=")))
        .unwrap_or_else(|| panic!("no {key}= in {reply:?}"))
        .to_string()
}

/// Submit on a workerless state, run the job inline, return `j<id>`.
fn solved_job(st: &ServerState, line: &str) -> String {
    let r = handle_line(st, line);
    assert!(r.starts_with("ok job="), "{r}");
    let id = field(&r, "job");
    assert!(st.drain_one(), "one queued job to run");
    assert!(handle_line(st, &format!("poll job={id}")).contains("state=done"), "{id}");
    id
}

#[test]
fn promote_assign_evict_lifecycle_over_tcp() {
    let h = serve(ServerConfig { workers: 2, ..Default::default() }).unwrap();
    let sub = request(h.addr, "submit dataset=blobs_400_4_3 k=3 seed=7").unwrap();
    let id = field(&sub, "job");
    let done = request(h.addr, &format!("wait job={id} timeout_ms=60000")).unwrap();
    assert!(done.starts_with("ok method="), "{done}");

    let p = request(h.addr, &format!("promote job={id} name=prod")).unwrap();
    assert!(p.starts_with("ok model=prod "), "{p}");
    assert_eq!(field(&p, "job"), id, "{p}");
    assert_eq!(field(&p, "k"), "3", "{p}");
    assert_eq!(field(&p, "dim"), "4", "{p}");
    assert_eq!(field(&p, "metric"), "l1", "{p}");
    // the promote reply's inertia is the solve's, verbatim
    assert_eq!(field(&p, "inertia"), field(&done, "inertia"), "{p}");

    let a = request(h.addr, "assign model=prod point=0,0,0,0 point=5,5,5,5").unwrap();
    assert!(a.starts_with("ok model=prod n=2 labels="), "{a}");
    assert_eq!(field(&a, "labels").split(',').count(), 2, "{a}");
    assert_eq!(field(&a, "dists").split(',').count(), 2, "{a}");
    let t = request(h.addr, "assign model=prod top2=1 point=1,2,3,4").unwrap();
    assert!(t.contains(" second=") && t.contains(" dists2="), "{t}");
    // per point, the nearest and runner-up medoid must differ
    assert_ne!(field(&t, "labels"), field(&t, "second"), "{t}");

    let m = request(h.addr, "models").unwrap();
    assert!(m.starts_with("ok count=1 "), "{m}");
    assert!(m.contains(" model.prod.method=OneBatch-nniw "), "{m}");
    assert!(m.contains(" model.prod.source=synth:blobs_400_4_3"), "{m}");

    // stats carries the registry gauge and the serving aggregates
    let s = request(h.addr, "stats").unwrap();
    assert!(s.contains(" models=1 "), "{s}");
    assert!(s.contains(" model.prod.assign_count=2 "), "{s}");

    let e = request(h.addr, "evict model=prod").unwrap();
    assert!(e.starts_with("ok evicted model=prod "), "{e}");
    let gone = request(h.addr, "assign model=prod point=0,0,0,0").unwrap();
    assert!(gone.starts_with("err unknown model prod"), "{gone}");
    h.shutdown();
}

#[test]
fn assign_matches_offline_argmin_with_no_dataset_resident() {
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    let sub = request(h.addr, "submit dataset=blobs_400_4_3 k=3 seed=11").unwrap();
    let id = field(&sub, "job");
    let done = request(h.addr, &format!("wait job={id} timeout_ms=60000")).unwrap();
    assert!(done.starts_with("ok method="), "{done}");
    assert!(request(h.addr, &format!("promote job={id} name=frozen"))
        .unwrap()
        .starts_with("ok model=frozen "));

    // drop every cached dataset: from here on the server owns nothing
    // but the model's k x p medoid rows
    h.state.cache.clear();
    let s = request(h.addr, "stats").unwrap();
    assert!(s.contains(" cache_entries=0 "), "{s}");

    // offline ground truth: regenerate the dataset the same way the
    // server did and argmin against the medoid indices it reported
    let x = DataSource::parse("synth:blobs_400_4_3").unwrap().load(1.0, 11).unwrap().x;
    let medoids: Vec<usize> =
        field(&done, "medoids").split(',').map(|t| t.parse().unwrap()).collect();
    let med_rows = x.select_rows(&medoids);
    let probes: Vec<Vec<f32>> = (0..10)
        .map(|i| {
            let mut row = x.row(i * 37).to_vec();
            row[i % 4] += 0.25; // off-manifold: not a training row
            row
        })
        .collect();

    let be = NativeBackend::new(Metric::L1);
    let points = obpam::linalg::Matrix::from_vec(
        probes.len(),
        4,
        probes.iter().flatten().copied().collect(),
    );
    let (want_labels, want_dists) = backend::assign(&be, &points, &med_rows).unwrap();

    let line = probes.iter().fold("assign model=frozen".to_string(), |mut l, row| {
        let joined: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        l.push_str(&format!(" point={}", joined.join(",")));
        l
    });
    let a = request(h.addr, &line).unwrap();
    assert!(a.starts_with("ok model=frozen n=10 "), "{a}");
    let got_labels: Vec<usize> =
        field(&a, "labels").split(',').map(|t| t.parse().unwrap()).collect();
    assert_eq!(got_labels, want_labels, "{a}");
    let want_fmt: Vec<String> = want_dists.iter().map(|d| format!("{d:.6}")).collect();
    assert_eq!(field(&a, "dists"), want_fmt.join(","), "{a}");

    // serving loaded nothing back into the cache
    let s = request(h.addr, "stats").unwrap();
    assert!(s.contains(" cache_entries=0 "), "{s}");
    h.shutdown();
}

#[test]
fn model_registry_lru_evicts_the_coldest_over_the_wire() {
    let st = ServerState::new(&ServerConfig { model_cap: 2, ..Default::default() });
    let id = solved_job(&st, "submit dataset=blobs_300_4_3 k=3 seed=1");
    for name in ["a", "b"] {
        assert!(handle_line(&st, &format!("promote job={id} name={name}")).starts_with("ok "));
    }
    // touch `a` so `b` is the coldest when `c` arrives
    assert!(handle_line(&st, "assign model=a point=0,0,0,0").starts_with("ok "));
    assert!(handle_line(&st, &format!("promote job={id} name=c")).starts_with("ok "));
    let m = handle_line(&st, "models");
    assert!(m.starts_with("ok count=2 cap=2 promoted=3 evicted=1"), "{m}");
    assert!(m.contains(" model.a.") && m.contains(" model.c."), "{m}");
    assert!(!m.contains(" model.b."), "LRU victim must be b: {m}");
    assert!(handle_line(&st, "assign model=b point=0,0,0,0").starts_with("err unknown model b"));
    // re-promoting an existing name replaces in place: no eviction
    assert!(handle_line(&st, &format!("promote job={id} name=c")).starts_with("ok model=c"));
    let m = handle_line(&st, "models");
    assert!(m.starts_with("ok count=2 cap=2 promoted=4 evicted=1"), "{m}");
}

#[test]
fn mismatched_assigns_err_instead_of_serving_garbage() {
    let st = ServerState::new(&ServerConfig::default());
    let id = solved_job(&st, "submit dataset=blobs_300_4_3 k=3 seed=2");
    assert!(handle_line(&st, &format!("promote job={id} name=m-ok")).starts_with("ok "));
    for (line, why) in [
        ("assign model=m-ok point=1,2,3", "dimension"),
        ("assign model=m-ok point=1,2,3,4,5", "dimension"),
        ("assign model=m-ok point=1,2,3,inf", "non-finite"),
        ("assign model=m-ok point=0,0,0,0 metric=l2", "metric"),
        ("assign model=m-ok point=0,0,0,0 metric=cosine", "metric"),
        ("assign model=m-ok", "no points"),
        ("assign model=m-ok point=0,0,0,0 top2=2", "top2 flag"),
    ] {
        let r = handle_line(&st, line);
        assert!(r.starts_with("err"), "{why}: {line:?} -> {r}");
    }
    // a promote of a running/queued job must also refuse cleanly
    assert!(handle_line(&st, "submit dataset=blobs_300_4_3 k=3 seed=3").starts_with("ok job="));
    let r = handle_line(&st, "promote job=j2");
    assert!(r.starts_with("err job j2 is queued"), "{r}");
}

#[test]
fn v5_reply_prefix_is_byte_identical_with_inertia_trailing() {
    // the v6 guarantee: the entire v5 field sequence survives in order,
    // with the v6 inertia= between the reply body and the connection
    // trailer — and v7's profile= appended right after it
    let h = serve(ServerConfig::default()).unwrap();
    let r = request(h.addr, "cluster dataset=blobs_300_4_3 k=3 seed=5").unwrap();
    let mut pos = 0;
    for f in [
        "ok method=", " cache=", " medoids=", " objective=", " seconds=", " dissim=", " swaps=",
        " source=", " cost=", " inertia=", " profile=", " queue_ms=", " served_ms=",
    ] {
        let at = r[pos..].find(f).unwrap_or_else(|| panic!("{f:?} missing/misordered in {r:?}"));
        pos += at + f.len();
    }
    h.shutdown();
}

#[test]
fn cancelled_fasterpam_job_releases_its_permit() {
    // ROADMAP 5b: FasterPAM observes SolveSpec::cancel between eager
    // passes, so a cancel landing mid-run aborts the solve and the
    // job's admission permit drains like any other terminal state
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    let sub = request(h.addr, "submit dataset=blobs_5000_8_5 k=5 seed=4 method=FasterPAM").unwrap();
    assert!(sub.starts_with("ok job="), "{sub}");
    let id = field(&sub, "job");
    assert!(h.state.admission.used() > 0, "admitted job holds its permit");
    for _ in 0..20_000 {
        if field(&request(h.addr, &format!("poll job={id}")).unwrap(), "state") != "queued" {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let c = request(h.addr, &format!("cancel job={id}")).unwrap();
    // cooperative: the cancel either lands mid-solve or the job won
    assert!(
        c.contains("cancel=requested") || c.contains("state=done") || c.contains("state=cancelled"),
        "{c}"
    );
    let fin = request(h.addr, &format!("wait job={id} timeout_ms=600000")).unwrap();
    assert!(
        fin.starts_with(&format!("err cancelled job={id}")) || fin.starts_with("ok method="),
        "cancelled or finished, nothing else: {fin}"
    );
    assert_eq!(h.state.admission.used(), 0, "terminal FasterPAM job must hold no budget");
    // a job that was cancelled mid-run captured no model
    if fin.starts_with("err cancelled") {
        let p = request(h.addr, &format!("promote job={id}")).unwrap();
        assert!(p.starts_with(&format!("err job {id} holds no model")), "{p}");
    }
    h.shutdown();
}
