//! Protocol v9 out-of-core serving from the outside: streamed
//! `npy:`/`dir:` OneBatch solves that never materialize the `n x p`
//! matrix, priced on the byte axis of the two-axis admission budget.
//!
//! The headline acceptance run (CI drives this under an
//! `OBPAM_THREADS` matrix of 1 and 4): a dataset whose resident
//! feature matrix **exceeds** the configured `--byte-budget` still
//! serves through the streaming path, bit-identical to the resident
//! solve of the same bytes, while a full-matrix method over the same
//! dataset is rejected at admission with a `bytes=`-priced error.
//! Alongside it: `dir:`/`npy:`/`synth:` tri-source bit-identity
//! (including an f32 round-trip through a CSV shard), malformed-source
//! errors, byte-budget non-starvation under a held streaming permit,
//! and the BanditPAM cancel-releases-permit regression over real TCP.

use obpam::backend::NativeBackend;
use obpam::data::npy::write_npy;
use obpam::data::synth;
use obpam::dissim::{ComputeProfile, DissimCounter, Metric};
use obpam::linalg::Matrix;
use obpam::server::{handle_line, request, serve, CacheStats, ServerConfig, ServerState};
use obpam::solver::{self, MethodSpec, SolveSpec};
use std::path::PathBuf;

/// Thread width under test (CI matrix: 1 and 4).
fn threads() -> usize {
    std::env::var("OBPAM_THREADS").ok().and_then(|s| s.parse().ok()).unwrap_or(2)
}

fn fresh_state() -> ServerState {
    ServerState::new(&ServerConfig::default())
}

/// A per-test scratch directory under the system temp dir.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("obpam_ooc_{}_{}", std::process::id(), name));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn medoids_of(reply: &str) -> String {
    reply.split("medoids=").nth(1).unwrap().split_whitespace().next().unwrap().to_string()
}

/// Extract `key=<token>` from a reply line.
fn field(reply: &str, key: &str) -> String {
    reply
        .split_whitespace()
        .find_map(|t| t.strip_prefix(&format!("{key}=")))
        .unwrap_or_else(|| panic!("no {key}= in {reply:?}"))
        .to_string()
}

/// The v9 acceptance criterion end to end, over real TCP: the dataset's
/// `n x p` feature matrix (20000 x 8 x 4 = 640 kB) exceeds the 400 kB
/// byte budget, so it can never be resident — yet OneBatch streams it
/// (batch slice + one chunk buffer fit with room to spare) and returns
/// the resident solve's exact bits, while FasterPAM over the same bytes
/// is refused at admission with the full-matrix byte price.
#[test]
fn streaming_solve_exceeding_byte_budget_matches_resident_bits() {
    let t = threads();
    let x = synth::generate("blobs_20000_8_5", 1.0, 7).x;
    let dir = scratch("accept");
    let path = dir.join("big.npy");
    write_npy(&path, &x).unwrap();

    const BUDGET: u64 = 400_000;
    let feat_bytes = (x.rows as u64) * (x.cols as u64) * 4;
    assert!(feat_bytes > BUDGET, "the dataset must not fit resident: {feat_bytes}");
    let h = serve(ServerConfig {
        byte_budget: BUDGET,
        strict_budget: true, // no lone-job idle exception on either axis
        ..Default::default()
    })
    .unwrap();

    // the streamed OneBatch solve is admitted: its price is the m x p
    // batch slice plus one chunk buffer, not the n x p matrix
    let r = request(
        h.addr,
        &format!("cluster dataset=npy:{} k=5 seed=7 m=300 threads={t}", path.display()),
    )
    .unwrap();
    assert!(r.starts_with("ok method=OneBatch-nniw cache=stream medoids="), "{r}");
    let streaming = MethodSpec::default().streaming_cost(x.rows, x.cols, 5, Some(300)).unwrap();
    assert!(streaming.resident_bytes <= BUDGET, "streaming price must fit the budget");
    assert_eq!(field(&r, "bytes"), streaming.resident_bytes.to_string(), "{r}");

    // a full-matrix method over the same dataset needs n*p + n*n
    // resident: rejected at admission, priced in bytes, before any load
    let rej = request(
        h.addr,
        &format!("cluster dataset=npy:{} k=5 method=FasterPAM threads={t}", path.display()),
    )
    .unwrap();
    assert!(rej.starts_with("err over byte budget: bytes="), "{rej}");
    let full = MethodSpec::FasterPam.cost_with_dims(x.rows, x.cols, 5, None);
    assert!(rej.contains(&format!("bytes={}", full.resident_bytes)), "{rej}");

    // the streamed medoids and objective are the resident solve's bits
    // for the same bytes (wire defaults: profile=fast, metric=l1; the
    // serial twin also pins thread-width independence under the matrix)
    let mut spec = SolveSpec::new(MethodSpec::default(), 5, 7);
    spec.m = Some(300);
    spec.profile = ComputeProfile::Fast;
    let backend = NativeBackend::new(Metric::L1).with_profile(ComputeProfile::Fast);
    let lib = solver::solve(&x, &spec, &backend).unwrap();
    let lib_medoids: Vec<String> = lib.medoids.iter().map(|m| m.to_string()).collect();
    assert_eq!(medoids_of(&r), lib_medoids.join(","), "{r}");
    let obj = obpam::eval::objective(&x, &lib.medoids, &DissimCounter::new(Metric::L1));
    assert!(r.contains(&format!(" objective={obj:.6} ")), "{r}");

    // nothing was cached (streams bypass the cache; the rejected job
    // never loaded) and every reservation was released
    let stats = request(h.addr, "stats").unwrap();
    assert!(stats.starts_with("ok cache_hits=0 cache_misses=0"), "{stats}");
    assert!(stats.contains(&format!(" mem_total={BUDGET} mem_used=0 ")), "{stats}");
    assert!(stats.contains(" budget_used=0 "), "{stats}");
    h.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// `synth:`, `npy:` and `dir:` spellings of the same 600 x 8 bytes
/// produce identical medoids, objective and inertia — including a CSV
/// shard round-trip (`{v}` Display prints the shortest string that
/// parses back to the same f32, so text shards lose nothing).
#[test]
fn dir_npy_and_synth_sources_agree_bit_for_bit() {
    let t = threads();
    let x = synth::generate("blobs_600_8_5", 1.0, 3).x;
    let dir = scratch("trisource");
    let npy_path = dir.join("whole.npy");
    write_npy(&npy_path, &x).unwrap();
    // shard dir: rows 0..250 as headerless CSV text, rows 250..600 as
    // binary npy, natural-ordered behind a 600-row manifest
    let shards = dir.join("shards");
    std::fs::create_dir_all(&shards).unwrap();
    let mut csv = String::new();
    for i in 0..250 {
        let row: Vec<String> = x.row(i).iter().map(|v| format!("{v}")).collect();
        csv.push_str(&row.join(","));
        csv.push('\n');
    }
    std::fs::write(shards.join("shard1.csv"), csv).unwrap();
    let tail = Matrix::from_vec(350, 8, x.data[250 * 8..].to_vec());
    write_npy(&shards.join("shard2.npy"), &tail).unwrap();
    std::fs::write(shards.join("manifest"), "600\n").unwrap();

    let st = fresh_state();
    let synth_r =
        handle_line(&st, &format!("cluster dataset=blobs_600_8_5 k=5 seed=3 threads={t}"));
    let npy_r = handle_line(
        &st,
        &format!("cluster dataset=npy:{} k=5 seed=3 threads={t}", npy_path.display()),
    );
    let dir_r = handle_line(
        &st,
        &format!("cluster dataset=dir:{} k=5 seed=3 threads={t}", shards.display()),
    );
    assert!(synth_r.starts_with("ok "), "{synth_r}");
    assert!(synth_r.contains("cache=miss"), "resident synth load: {synth_r}");
    for (tag, r) in [("npy", &npy_r), ("dir", &dir_r)] {
        assert!(r.starts_with("ok "), "{tag}: {r}");
        assert!(r.contains("cache=stream"), "{tag} must stream: {r}");
        assert_eq!(medoids_of(&synth_r), medoids_of(r), "{tag}: {r}");
        assert_eq!(field(&synth_r, "objective"), field(r, "objective"), "{tag}: {r}");
        assert_eq!(field(&synth_r, "inertia"), field(r, "inertia"), "{tag}: {r}");
    }
    // only the resident synth run touched the cache
    let s = st.cache.stats();
    assert_eq!((s.hits, s.misses, s.entries), (0, 1, 1));
    std::fs::remove_dir_all(&dir).ok();
}

/// Malformed streams fail with source-shaped errors, never a solve over
/// garbage bytes: a non-npy file, an npy whose payload was truncated
/// after its (valid) header was probed, and a shard dir whose manifest
/// disagrees with the rows its shards actually hold.
#[test]
fn malformed_stream_sources_error_cleanly() {
    let st = fresh_state();
    let dir = scratch("malformed");

    let bogus = dir.join("bogus.npy");
    std::fs::write(&bogus, b"this is not numpy data at all").unwrap();
    let r = handle_line(&st, &format!("cluster dataset=npy:{} k=3", bogus.display()));
    assert!(r.starts_with("err"), "{r}");
    assert!(r.contains("npy magic"), "{r}");

    // a valid header over a cut-short payload: the cheap pre-admission
    // probe succeeds, the sweep hits EOF mid-row
    let cut = dir.join("cut.npy");
    let x = synth::generate("blobs_100_4_3", 1.0, 1).x;
    write_npy(&cut, &x).unwrap();
    let len = std::fs::metadata(&cut).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&cut).unwrap();
    f.set_len(len - 700).unwrap();
    drop(f);
    let r = handle_line(&st, &format!("cluster dataset=npy:{} k=3 seed=1", cut.display()));
    assert!(r.starts_with("err"), "{r}");
    assert!(r.contains("truncated npy"), "{r}");

    // manifest/shard disagreement is an open error, never a short read
    let shards = dir.join("shards");
    std::fs::create_dir_all(&shards).unwrap();
    std::fs::write(shards.join("shard1.csv"), "0,1\n2,3\n4,5\n").unwrap();
    std::fs::write(shards.join("manifest"), "9\n").unwrap();
    let r = handle_line(&st, &format!("cluster dataset=dir:{} k=2", shards.display()));
    assert!(r.starts_with("err"), "{r}");
    assert!(r.contains("manifest says 9 rows"), "{r}");

    // none of the failures loaded, cached, or leaked a reservation
    assert_eq!(st.cache.stats(), CacheStats::default());
    assert_eq!((st.admission.used(), st.admission.bytes_used()), (0, 0));
    std::fs::remove_dir_all(&dir).ok();
}

/// A huge streamed dataset cannot starve the byte budget: its hold is
/// the batch slice + one chunk buffer (constant in `n`), so small
/// resident jobs keep fitting next to it, a genuinely over-budget
/// full-matrix job is refused with both prices in the error, and the
/// release restores the full budget.
#[test]
fn held_streaming_permit_does_not_starve_small_resident_jobs() {
    let st = ServerState::new(&ServerConfig {
        byte_budget: 1_000_000,
        strict_budget: true,
        ..Default::default()
    });
    // the streaming price of a 1M x 8 dataset: 32 MB resident, ~140 kB
    // streamed — hold it as a long-running streamed job would
    let huge = MethodSpec::default().streaming_cost(1_000_000, 8, 5, Some(300)).unwrap();
    assert!(huge.resident_bytes < 200_000, "streaming price is n-independent");
    let hold = st.admission.try_admit_costed(huge.units, huge.resident_bytes).unwrap();

    // a small resident job fits beside the stream's hold
    let r = handle_line(&st, "cluster dataset=blobs_300_4_3 k=3 seed=1");
    assert!(r.starts_with("ok "), "{r}");

    // a full-matrix job over the remaining headroom is refused, priced
    // at its pre-load bytes (synth width is unknown before the load, so
    // the prediction prices features at zero width; the n*n*4 distance
    // matrix dominates and already does not fit beside the hold)
    let pre = MethodSpec::FasterPam.cost_with_dims(480, 0, 4, None);
    let rej = handle_line(&st, "cluster dataset=blobs_480_8_4 k=4 method=FasterPAM");
    assert!(
        rej.starts_with(&format!("err over byte budget: bytes={}", pre.resident_bytes)),
        "{rej}"
    );
    assert!(rej.contains(&format!("(in use {})", huge.resident_bytes)), "{rej}");

    // releasing the stream's hold restores the budget and the same job
    // admits (the nonzero pre-load hold is kept — only a zero byte
    // hold or a wrong row prediction triggers the post-load reprice)
    drop(hold);
    assert_eq!((st.admission.used(), st.admission.bytes_used()), (0, 0));
    let ok = handle_line(&st, "cluster dataset=blobs_480_8_4 k=4 method=FasterPAM");
    assert!(ok.starts_with("ok method=FasterPAM "), "{ok}");
    assert_eq!(field(&ok, "bytes"), pre.resident_bytes.to_string(), "{ok}");
    assert_eq!((st.admission.used(), st.admission.bytes_used()), (0, 0));
}

/// Cancelling a *running* BanditPAM job over TCP releases its admission
/// permit on both axes — the v9 regression for the between-rounds
/// cancel checks (before them, a cancelled BanditPAM ran to completion
/// holding its quadratic reservation the whole way).
#[test]
fn cancelled_running_banditpam_releases_admission_permit_over_tcp() {
    let h = serve(ServerConfig { workers: 1, ..Default::default() }).unwrap();
    let sub = request(h.addr, "submit dataset=blobs_20000_8_5 k=5 seed=3 method=BanditPAM++-2")
        .unwrap();
    assert!(sub.starts_with("ok job="), "{sub}");
    let id = field(&sub, "job");
    // wait for worker pickup so the cancel lands on a running solve
    let mut state = String::new();
    for _ in 0..20_000 {
        let r = request(h.addr, &format!("poll job={id}")).unwrap();
        state = field(&r, "state");
        if state != "queued" {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(state, "running", "BanditPAM at n=20000 outlives the poll loop");
    let c = request(h.addr, &format!("cancel job={id}")).unwrap();
    // cooperative cancellation: the request lands between batch rounds,
    // unless the job beat it to a terminal state
    assert!(
        c.contains("cancel=requested") || c.contains("state=done") || c.contains("state=cancelled"),
        "{c}"
    );
    let fin = request(h.addr, &format!("wait job={id} timeout_ms=600000")).unwrap();
    assert!(
        fin.starts_with(&format!("err cancelled job={id}")) || fin.starts_with("ok method="),
        "cancelled or finished, nothing else: {fin}"
    );
    // terminal on either path — the quadratic unit hold and the
    // resident byte hold are both gone
    assert_eq!(h.state.admission.used(), 0, "units released at terminal state");
    assert_eq!(h.state.admission.bytes_used(), 0, "bytes released at terminal state");
    h.shutdown();
}
