//! Parallel-vs-serial equivalence suite for the runtime::pool execution
//! layer, plus the server backpressure contract.
//!
//! The parallel layer's promise is strict: for a fixed seed, every
//! result — the pairwise matrix, the tile ops, the full `one_batch_pam`
//! medoid selection — is **bit-identical** at any thread count.  These
//! tests pin that promise at {1, 2, 4, 8} threads (and auto), and —
//! since the pool is a persistent set of parked workers rather than
//! scoped spawns — also across **many parallel regions reusing one pool
//! instance** (the shape a served job actually runs: one pool, many
//! pairwise/tile/scan regions).  CI repeats the suite under an
//! `OBPAM_THREADS` matrix (1 and 4); the env count joins the compared
//! widths below.

use obpam::backend::{ComputeBackend, NativeBackend};
use obpam::coordinator::{one_batch_pam, OneBatchConfig, SamplerKind};
use obpam::dissim::{cross_matrix_pool, ComputeProfile, DissimCounter, Metric};
use obpam::linalg::Matrix;
use obpam::rng::Rng;
use obpam::runtime::Pool;
use obpam::server::{request, serve, ServerConfig};

fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
    Matrix::from_vec(r, c, (0..r * c).map(|_| rng.f32()).collect())
}

#[test]
fn pairwise_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(0xA11);
    // odd shapes on purpose: exercise ragged chunk boundaries
    let x = rand_matrix(&mut rng, 301, 17);
    let b = rand_matrix(&mut rng, 67, 17);
    for metric in [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev, Metric::Cosine] {
        let serial = cross_matrix_pool(&DissimCounter::new(metric), &x, &b, &Pool::serial());
        for threads in [2, 4] {
            let par =
                cross_matrix_pool(&DissimCounter::new(metric), &x, &b, &Pool::new(threads));
            // Vec<f32> equality is bitwise for non-NaN values; distances
            // are never NaN here
            assert_eq!(
                par.data,
                serial.data,
                "{} differs at {threads} threads",
                metric.name()
            );
        }
    }
}

#[test]
fn pairwise_counts_dissims_once_regardless_of_threads() {
    let mut rng = Rng::new(0xA12);
    let x = rand_matrix(&mut rng, 50, 5);
    let b = rand_matrix(&mut rng, 9, 5);
    for threads in [1, 2, 4] {
        let d = DissimCounter::new(Metric::L1);
        cross_matrix_pool(&d, &x, &b, &Pool::new(threads));
        assert_eq!(d.count(), 50 * 9, "threads={threads}");
    }
}

#[test]
fn one_batch_pam_medoids_identical_at_any_thread_count() {
    let mut rng = Rng::new(0xA13);
    let x = rand_matrix(&mut rng, 600, 12);
    for sampler in [SamplerKind::Unif, SamplerKind::Nniw, SamplerKind::Lwcs] {
        let run = |threads: usize| {
            let backend = NativeBackend::with_pool(Metric::L1, Pool::new(threads));
            let cfg = OneBatchConfig {
                k: 6,
                sampler,
                m: Some(120),
                seed: 77,
                threads,
                ..Default::default()
            };
            one_batch_pam(&x, &cfg, &backend).unwrap()
        };
        let serial = run(1);
        for threads in [2, 4, 0] {
            let par = run(threads);
            assert_eq!(
                par.medoids,
                serial.medoids,
                "{} medoids differ at {threads} threads",
                sampler.name()
            );
            assert_eq!(
                par.est_objective.to_bits(),
                serial.est_objective.to_bits(),
                "{} objective bits differ at {threads} threads",
                sampler.name()
            );
            assert_eq!(
                par.stats.dissim_count, serial.stats.dissim_count,
                "{} dissim count differs at {threads} threads",
                sampler.name()
            );
            assert_eq!(
                par.stats.swap_count, serial.stats.swap_count,
                "{} swap count differs at {threads} threads",
                sampler.name()
            );
        }
    }
}

#[test]
fn backend_tile_ops_identical_across_thread_counts() {
    let mut rng = Rng::new(0xA14);
    let (n, m, k) = (211, 40, 9);
    let d = rand_matrix(&mut rng, n, m);
    let dmk = rand_matrix(&mut rng, n, k);
    let dn: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
    let ds: Vec<f32> = dn.iter().map(|v| v + 0.25).collect();
    let near: Vec<usize> = (0..m).map(|_| rng.below(k)).collect();
    let w: Vec<f32> = (0..m).map(|_| 0.5 + rng.f32()).collect();

    let serial = NativeBackend::new(Metric::L1);
    let top2_s = serial.top2(&dmk).unwrap();
    let argmin_s = serial.argmin_rows(&d).unwrap();
    let gains_s = serial.gains(&d, &dn, &ds, &near, k, &w).unwrap();
    for threads in [2, 4] {
        let par = NativeBackend::with_pool(Metric::L1, Pool::new(threads));
        assert_eq!(par.top2(&dmk).unwrap(), top2_s, "top2 at {threads} threads");
        assert_eq!(par.argmin_rows(&d).unwrap(), argmin_s, "argmin at {threads} threads");
        let gains_p = par.gains(&d, &dn, &ds, &near, k, &w).unwrap();
        assert_eq!(gains_p.0, gains_s.0, "shared gains at {threads} threads");
        assert_eq!(gains_p.1.data, gains_s.1.data, "permedoid gains at {threads} threads");
    }
}

#[test]
fn fused_tile_ops_bit_identical_across_thread_counts() {
    // the fused single-sweep ops (pairwise_argmin / pairwise_top2) must
    // be bit-identical to the serial run AND to the unfused
    // materialise-then-rewalk composition, at every compared width,
    // under both compute profiles
    let mut rng = Rng::new(0xA17);
    let x = rand_matrix(&mut rng, 301, 17);
    let b = rand_matrix(&mut rng, 67, 17);
    for metric in [Metric::L1, Metric::L2, Metric::SqL2, Metric::Chebyshev, Metric::Cosine] {
        for profile in [ComputeProfile::Exact, ComputeProfile::Fast] {
            let serial = NativeBackend::new(metric).with_profile(profile);
            let (d_s, idx_s, val_s) = serial.pairwise_argmin(&x, &b).unwrap();
            let (d2_s, t2_s) = serial.pairwise_top2(&x, &b).unwrap();
            assert_eq!(d_s.data, d2_s.data, "argmin/top2 sweeps disagree on the matrix");
            assert_eq!(
                serial.argmin_rows(&d_s).unwrap(),
                (idx_s.clone(), val_s.clone()),
                "{} {} fused argmin != unfused rewalk",
                metric.name(),
                profile.name()
            );
            assert_eq!(
                serial.top2(&d_s).unwrap(),
                t2_s,
                "{} {} fused top2 != unfused rewalk",
                metric.name(),
                profile.name()
            );
            for threads in reuse_thread_counts() {
                let par =
                    NativeBackend::with_pool(metric, Pool::new(threads)).with_profile(profile);
                let (d_p, idx_p, val_p) = par.pairwise_argmin(&x, &b).unwrap();
                let tag = format!("{} {} at {threads} threads", metric.name(), profile.name());
                assert_eq!(d_p.data, d_s.data, "argmin matrix: {tag}");
                assert_eq!(idx_p, idx_s, "argmin indices: {tag}");
                assert_eq!(val_p, val_s, "argmin values: {tag}");
                let (d2_p, t2_p) = par.pairwise_top2(&x, &b).unwrap();
                assert_eq!(d2_p.data, d_s.data, "top2 matrix: {tag}");
                assert_eq!(t2_p, t2_s, "top2 reduction: {tag}");
            }
        }
    }
}

#[test]
fn fast_profile_solve_identical_at_any_thread_count() {
    // the dot-product Fast kernel must stay deterministic under
    // threading just like Exact: the batch norms are precomputed once
    // and every row reduction is chunk-independent
    let mut rng = Rng::new(0xA18);
    let x = rand_matrix(&mut rng, 400, 9);
    let run = |threads: usize| {
        let backend = NativeBackend::with_pool(Metric::SqL2, Pool::new(threads))
            .with_profile(ComputeProfile::Fast);
        let cfg = OneBatchConfig {
            k: 5,
            sampler: SamplerKind::Nniw,
            m: Some(90),
            seed: 33,
            threads,
            profile: ComputeProfile::Fast,
            ..Default::default()
        };
        one_batch_pam(&x, &cfg, &backend).unwrap()
    };
    let serial = run(1);
    for threads in reuse_thread_counts() {
        let par = run(threads);
        assert_eq!(par.medoids, serial.medoids, "fast medoids differ at {threads} threads");
        assert_eq!(
            par.est_objective.to_bits(),
            serial.est_objective.to_bits(),
            "fast objective bits differ at {threads} threads"
        );
        assert_eq!(
            par.stats.dissim_count, serial.stats.dissim_count,
            "fast dissim count differs at {threads} threads"
        );
    }
}

/// Thread counts the reuse tests compare against serial: the acceptance
/// set {1, 2, 8} plus whatever width CI's `OBPAM_THREADS` matrix asks
/// for on this run.
fn reuse_thread_counts() -> Vec<usize> {
    let mut counts = vec![1, 2, 8];
    if let Some(t) = std::env::var("OBPAM_THREADS").ok().and_then(|s| s.parse::<usize>().ok()) {
        if t != 0 && !counts.contains(&t) {
            counts.push(t);
        }
    }
    counts
}

#[test]
fn reused_pool_repeated_regions_bit_identical() {
    // ONE pool instance per width drives repeated parallel regions of
    // several shapes (pairwise, argmin, top2); every round must be
    // bit-identical to the serial result — pool reuse must not leak any
    // state from region to region
    let mut rng = Rng::new(0xA15);
    let x = rand_matrix(&mut rng, 257, 13);
    let b = rand_matrix(&mut rng, 41, 13);
    let serial = NativeBackend::new(Metric::L1);
    let d_s = cross_matrix_pool(&DissimCounter::new(Metric::L1), &x, &b, &Pool::serial());
    let argmin_s = serial.argmin_rows(&d_s).unwrap();
    let top2_s = serial.top2(&d_s).unwrap();
    for threads in reuse_thread_counts() {
        let pool = Pool::new(threads);
        let backend = NativeBackend::with_pool(Metric::L1, pool.clone());
        for round in 0..5 {
            let d = cross_matrix_pool(&DissimCounter::new(Metric::L1), &x, &b, &pool);
            assert_eq!(d.data, d_s.data, "pairwise round {round} at {threads} threads");
            assert_eq!(
                backend.argmin_rows(&d).unwrap(),
                argmin_s,
                "argmin round {round} at {threads} threads"
            );
            assert_eq!(
                backend.top2(&d).unwrap(),
                top2_s,
                "top2 round {round} at {threads} threads"
            );
        }
    }
}

#[test]
fn repeated_solves_on_one_reused_pool_identical() {
    // the serving shape: one pool (via one backend) runs several full
    // OneBatchPAM solves back to back; medoids and objective bits must
    // match the serial solve every time, at 1, 2 and 8 threads
    let mut rng = Rng::new(0xA16);
    let x = rand_matrix(&mut rng, 500, 10);
    let solve = |backend: &NativeBackend, threads: usize| {
        let cfg = OneBatchConfig {
            k: 5,
            sampler: SamplerKind::Nniw,
            m: Some(100),
            seed: 21,
            threads,
            ..Default::default()
        };
        one_batch_pam(&x, &cfg, backend).unwrap()
    };
    let serial = solve(&NativeBackend::new(Metric::L1), 1);
    for threads in reuse_thread_counts() {
        let backend = NativeBackend::with_pool(Metric::L1, Pool::new(threads));
        for round in 0..3 {
            let r = solve(&backend, threads);
            assert_eq!(
                r.medoids, serial.medoids,
                "medoids differ on round {round} at {threads} threads"
            );
            assert_eq!(
                r.est_objective.to_bits(),
                serial.est_objective.to_bits(),
                "objective bits differ on round {round} at {threads} threads"
            );
            assert_eq!(
                r.stats.dissim_count, serial.stats.dissim_count,
                "dissim count differs on round {round} at {threads} threads"
            );
        }
    }
}

/// Fire far more concurrent jobs than `queue_cap` at a slow endpoint and
/// check the admission contract: every connection gets exactly one reply,
/// rejected ones get `err queue full`, and the number of *served* jobs
/// can never exceed what a cap-bounded queue could admit — i.e. the
/// check-then-increment overshoot is gone.
#[test]
fn server_burst_backpressure_bounds_inflight_jobs() {
    let queue_cap = 2;
    let burst = 12;
    let h = serve(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap,
        ..Default::default()
    })
    .unwrap();

    let handles: Vec<_> = (0..burst)
        .map(|_| {
            let addr = h.addr;
            std::thread::spawn(move || request(addr, "sleep ms=400").unwrap())
        })
        .collect();
    let replies: Vec<String> = handles.into_iter().map(|t| t.join().unwrap()).collect();
    h.shutdown();

    assert_eq!(replies.len(), burst, "every connection must get a reply");
    let served = replies.iter().filter(|r| r.starts_with("ok slept_ms=400")).count();
    let rejected = replies.iter().filter(|r| r.starts_with("err queue full")).count();
    assert_eq!(served + rejected, burst, "unexpected reply in {replies:?}");
    assert!(rejected > 0, "burst of {burst} over cap {queue_cap} must reject some jobs");
    // With one worker on 400 ms jobs and a simultaneous burst, only the
    // first `queue_cap` connections fit in the system; allow generous
    // scheduling slack but far below the old unbounded behaviour.
    assert!(
        served <= queue_cap + 2,
        "admission exceeded the in-flight bound: {served} served (cap {queue_cap})"
    );
}

/// Server replies are identical whether the job ran serial or threaded.
#[test]
fn server_threaded_jobs_match_serial_jobs() {
    let h = serve(ServerConfig::default()).unwrap();
    // strip wall-clock and the cache field (the second identical request
    // is served from the dataset cache — same data, different tag)
    let strip = |r: String| {
        r.split(" seconds=").next().unwrap().replace("cache=hit", "cache=miss")
    };
    let a = strip(request(h.addr, "cluster dataset=blobs_400_4_3 k=3 seed=2 threads=1").unwrap());
    let b = strip(request(h.addr, "cluster dataset=blobs_400_4_3 k=3 seed=2 threads=4").unwrap());
    h.shutdown();
    assert!(a.starts_with("ok method=OneBatch-nniw"), "{a}");
    assert_eq!(a, b);
}

/// The server-owned pool cache (protocol v5): repeated threaded jobs
/// reuse ONE persistent pool per width — the cache must report exactly
/// the widths seen, and reuse must stay bit-identical to the serial
/// reply across many jobs and mixed widths.
#[test]
fn server_pool_cache_reuse_is_deterministic() {
    let h = serve(ServerConfig { workers: 2, ..Default::default() }).unwrap();
    let strip = |r: String| {
        r.split(" seconds=").next().unwrap().replace("cache=hit", "cache=miss")
    };
    let line =
        |threads: usize| format!("cluster dataset=blobs_400_4_3 k=3 seed=6 threads={threads}");
    let serial = strip(request(h.addr, &line(1)).unwrap());
    assert!(serial.starts_with("ok method="), "{serial}");
    // several width-4 jobs in a row: all share the cached width-4 pool
    for round in 0..3 {
        let r = strip(request(h.addr, &line(4)).unwrap());
        assert_eq!(r, serial, "pool-reuse round {round} diverged");
    }
    // interleave another width; determinism must survive the mix
    let w2 = strip(request(h.addr, &line(2)).unwrap());
    assert_eq!(w2, serial);
    let again = strip(request(h.addr, &line(4)).unwrap());
    assert_eq!(again, serial);
    // exactly one pool per distinct width (1, 2 and 4), built once each
    assert_eq!(h.state.pools.widths(), 3, "one cached pool per width");
    h.shutdown();
}
