//! Cross-method property tests for the unified `solver` API: every
//! method of the paper's Table 3 grid must run through the same
//! `solve()` entry point and produce valid, deterministic results.

use obpam::backend::NativeBackend;
use obpam::data::synth;
use obpam::dissim::{DissimCounter, Metric};
use obpam::eval;
use obpam::rng::Rng;
use obpam::solver::{self, MethodSpec, SolveSpec};

/// Valid medoids, finite objective, nonzero counted dissimilarities
/// (except Random, which computes none by construction), and exact
/// seed-determinism — for all 18 Table 3 rows.
#[test]
fn every_table3_method_solves_validly_and_deterministically() {
    let mut rng = Rng::new(3);
    let x = synth::gen_gaussian_mixture(&mut rng, 150, 4, 3, 0.15, 1.0);
    let eval_d = DissimCounter::new(Metric::L1);
    for method in MethodSpec::table3_grid() {
        let label = method.label();
        let spec = SolveSpec::new(method, 3, 9);
        let run = || {
            let backend = NativeBackend::new(Metric::L1);
            solver::solve(&x, &spec, &backend).unwrap()
        };
        let a = run();
        let b = run();
        // solve() validated uniqueness/range internally; spot-check anyway
        assert_eq!(a.medoids.len(), 3, "{label}");
        assert!(a.medoids.iter().all(|&m| m < x.rows), "{label}");
        let obj = eval::objective(&x, &a.medoids, &eval_d);
        assert!(obj.is_finite() && obj >= 0.0, "{label}: objective {obj}");
        if label != "Random" {
            assert!(a.stats.dissim_count > 0, "{label}: no counted dissimilarities");
        }
        assert_eq!(a.medoids, b.medoids, "{label}: not seed-deterministic");
        assert_eq!(a.stats.dissim_count, b.stats.dissim_count, "{label}: dissim count varies");
    }
}

/// The steepest swap engine is reachable through the string API too.
#[test]
fn steepest_variant_runs_through_parsed_label() {
    let mut rng = Rng::new(4);
    let x = synth::gen_gaussian_mixture(&mut rng, 120, 4, 3, 0.15, 1.0);
    let method = MethodSpec::parse("OneBatch-nniw-steepest").unwrap();
    let backend = NativeBackend::new(Metric::L1);
    let r = solver::solve(&x, &SolveSpec::new(method, 3, 2), &backend).unwrap();
    assert_eq!(r.medoids.len(), 3);
    assert!(r.est_objective.is_finite());
}

/// A different seed must be able to change the selection (the spec's
/// seed actually reaches every algorithm): check it on a seeding-driven
/// method where the first medoid is drawn directly from the RNG.
#[test]
fn seed_reaches_the_algorithms() {
    let mut rng = Rng::new(5);
    let x = synth::gen_gaussian_mixture(&mut rng, 200, 4, 4, 0.3, 1.0);
    let backend = NativeBackend::new(Metric::L1);
    let run = |seed: u64| {
        solver::solve(&x, &SolveSpec::new(MethodSpec::Random, 4, seed), &backend)
            .unwrap()
            .medoids
    };
    let distinct: std::collections::HashSet<Vec<usize>> = (0..8).map(run).collect();
    assert!(distinct.len() > 1, "8 seeds produced identical random selections");
}
