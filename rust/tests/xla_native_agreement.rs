//! XLA backend == native backend, numerically, on all four tile ops and
//! end-to-end.  These tests need the `xla` build feature plus
//! `artifacts/` (run `make artifacts`); if the manifest is missing they
//! print a notice and pass vacuously so the pure-Rust test suite stays
//! runnable.
#![cfg(feature = "xla")]

use obpam::backend::{ComputeBackend, NativeBackend, XlaBackend};
use obpam::coordinator::{one_batch_pam, OneBatchConfig, SamplerKind};
use obpam::dissim::Metric;
use obpam::linalg::Matrix;
use obpam::rng::Rng;
use obpam::runtime::Runtime;
use std::rc::Rc;

fn runtime() -> Option<Rc<Runtime>> {
    match Runtime::load_default() {
        Ok(rt) => Some(Rc::new(rt)),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
    Matrix::from_vec(r, c, (0..r * c).map(|_| rng.f32() * 2.0 - 0.5).collect())
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn pairwise_agrees_all_metrics_and_kinds() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    // shapes that exercise padding: n crosses the tile, p/m off-bucket
    for (n, m, p) in [(10, 7, 5), (300, 130, 60), (2100, 300, 100)] {
        let x = rand_matrix(&mut rng, n, p);
        let b = rand_matrix(&mut rng, m, p);
        for metric in [Metric::L1, Metric::SqL2, Metric::L2] {
            let native = NativeBackend::new(metric).pairwise(&x, &b).unwrap();
            for dense in [false, true] {
                let xla = XlaBackend::new(rt.clone(), metric, dense)
                    .pairwise(&x, &b)
                    .unwrap();
                assert_close(
                    &native.data,
                    &xla.data,
                    2e-3,
                    &format!("pairwise {} dense={dense} n={n}", metric.name()),
                );
            }
        }
    }
}

#[test]
fn top2_and_argmin_agree() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    let xla = XlaBackend::new(rt, Metric::L1, false);
    let native = NativeBackend::new(Metric::L1);
    for (n, k) in [(50, 3), (2100, 9), (100, 60)] {
        let d = rand_matrix(&mut rng, n, k.max(2));
        let (ni_n, nd_n, si_n, sd_n) = native.top2(&d).unwrap();
        let (ni_x, nd_x, si_x, sd_x) = xla.top2(&d).unwrap();
        assert_eq!(ni_n, ni_x, "near idx n={n} k={k}");
        assert_eq!(si_n, si_x, "sec idx n={n} k={k}");
        assert_close(&nd_n, &nd_x, 1e-5, "dnear");
        assert_close(&sd_n, &sd_x, 1e-5, "dsec");
    }
    for (n, m) in [(64, 17), (2100, 200)] {
        let d = rand_matrix(&mut rng, n, m);
        let (i_n, v_n) = native.argmin_rows(&d).unwrap();
        let (i_x, v_x) = xla.argmin_rows(&d).unwrap();
        assert_eq!(i_n, i_x, "argmin idx n={n} m={m}");
        assert_close(&v_n, &v_x, 1e-5, "argmin val");
    }
}

#[test]
fn gains_agree() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(3);
    let xla = XlaBackend::new(rt, Metric::L1, false);
    let native = NativeBackend::new(Metric::L1);
    for (n, m, k) in [(40, 11, 3), (2100, 200, 9), (128, 250, 45)] {
        let d = rand_matrix(&mut rng, n, m);
        let dn: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
        let ds: Vec<f32> = dn.iter().map(|v| v + rng.f32()).collect();
        let near: Vec<usize> = (0..m).map(|_| rng.below(k)).collect();
        let w: Vec<f32> = (0..m).map(|_| 0.5 + rng.f32()).collect();
        let (sh_n, pm_n) = native.gains(&d, &dn, &ds, &near, k, &w).unwrap();
        let (sh_x, pm_x) = xla.gains(&d, &dn, &ds, &near, k, &w).unwrap();
        assert_close(&sh_n, &sh_x, 2e-3, &format!("gains shared n={n} m={m} k={k}"));
        assert_close(&pm_n.data, &pm_x.data, 2e-3, "gains permedoid");
    }
}

#[test]
fn one_batch_pam_same_medoids_both_backends() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(4);
    let x = obpam::data::synth::gen_gaussian_mixture(&mut rng, 400, 8, 4, 0.15, 1.0);
    for sampler in [SamplerKind::Unif, SamplerKind::Debias, SamplerKind::Nniw] {
        let cfg = OneBatchConfig { k: 4, sampler, m: Some(60), seed: 9, ..Default::default() };
        let native = NativeBackend::new(Metric::L1);
        let r_n = one_batch_pam(&x, &cfg, &native).unwrap();
        let xla = XlaBackend::new(rt.clone(), Metric::L1, false);
        let r_x = one_batch_pam(&x, &cfg, &xla).unwrap();
        // identical seeds + deterministic pipeline -> identical medoids,
        // modulo FP ties; compare objectives tightly instead of indices.
        assert!(
            (r_n.est_objective - r_x.est_objective).abs()
                <= 1e-3 * r_n.est_objective.abs().max(1e-9),
            "{}: native {} vs xla {}",
            sampler.name(),
            r_n.est_objective,
            r_x.est_objective
        );
    }
}
