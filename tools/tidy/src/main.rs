//! In-tree static analysis for the obpam workspace, in the style of
//! rustc's `tidy`: a dependency-free line scanner that enforces the
//! concurrency and layering invariants the compiler cannot see.  Run it
//! directly (`cargo run -p tidy`) or as a test (`cargo test -p tidy`);
//! CI gates on both.  The full catalogue, with the invariant each lint
//! guards and the allowlist policy, lives in `docs/INVARIANTS.md`.
//!
//! Lints (names are what `// tidy:allow(<name>)` suppresses, placed on
//! the offending line or in the contiguous comment block above it):
//!
//! * `safety-comment` — every `unsafe` block / fn / impl must carry a
//!   `// SAFETY:` comment (or a `# Safety` doc section) stating the
//!   invariant that makes it sound.  `unsafe fn(...)` *types* (fn
//!   pointers) are not unsafe sites and are skipped.
//! * `thread-spawn` — `thread::spawn` only in `runtime/pool.rs` (the
//!   one sanctioned thread owner), `server/event.rs` (the evented
//!   accept core — the single accept-path spawn site), tests and
//!   benches; the server's solver-worker fleet carries an explicit
//!   `tidy:allow` annotation.
//! * `lock-discipline` — no raw `.lock().unwrap()` / `.expect()` (nor
//!   inline `unwrap_or_else(|e| e.into_inner())` poison recovery)
//!   outside `sync_ext`, which owns the recover-don't-propagate policy.
//! * `data-source` — no direct `synth::try_generate` / `load_csv` /
//!   `load_npy` / npy parsing (`parse_header`, `NpyReader::open`) /
//!   raw `File::open` calls outside `rust/src/data/`: all dataset
//!   access goes through URI-addressed `DataSource`s and `RowStore`s.
//!   The published header probe `npy::read_header` is the sanctioned
//!   pre-admission API and stays callable anywhere.
//! * `relaxed-ordering` — no `Ordering::Relaxed` outside the
//!   stat-counter allowlist (`telemetry.rs`, `server/cache.rs`):
//!   admission and registry atomics synchronise real state and must
//!   not be demoted silently.
//! * `verb-coverage` — every wire verb dispatched in `server/mod.rs`
//!   has a counter in `metrics::VERBS` and a mention in the protocol
//!   doc block, and every `VERBS` entry is actually dispatched.
//!
//! The scanner strips comments and string/char literals with a small
//! cross-line state machine (nested block comments, multi-line and raw
//! strings), so `"unsafe"` in a string or `.lock()` in a doc comment
//! never trips a lint.  It is a *line* scanner: a chain split across
//! lines (`.lock()\n.unwrap()`) can evade `lock-discipline` — the
//! lint is a tripwire for the idiom, not a soundness proof.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// Every lint the scanner knows, i.e. every name `tidy:allow(..)`
/// accepts.  Kept in one place so docs and tests can enumerate them.
pub const LINT_NAMES: [&str; 6] = [
    "safety-comment",
    "thread-spawn",
    "lock-discipline",
    "data-source",
    "relaxed-ordering",
    "verb-coverage",
];

/// One finding: `file:line: [lint] message`, repo-relative path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Repo-relative path, forward slashes (`rust/src/server/mod.rs`).
    pub file: String,
    /// 1-based line number of the offending line.
    pub line: usize,
    /// Lint name, one of [`LINT_NAMES`].
    pub lint: &'static str,
    /// Human explanation of what tripped and what the policy is.
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.lint, self.msg)
    }
}

/// A source line split into its syntactic layers by [`scan`].
struct Line {
    /// Code with comments removed; string/char literals kept verbatim
    /// (for verb extraction, which reads `Some("ping")`).
    code: String,
    /// Code with comments removed *and* string/char literal contents
    /// blanked — the view token lints match against.
    nostr: String,
    /// Comment text on the line, markers included (`// SAFETY: ...`).
    comment: String,
}

impl Line {
    fn is_comment_only(&self) -> bool {
        self.code.trim().is_empty() && !self.comment.trim().is_empty()
    }
}

/// Cross-line lexer state: where a line *ends* determines how the next
/// one starts (multi-line strings, nested block comments).
enum Mode {
    Code,
    LineComment,
    /// Nesting depth — Rust block comments nest.
    BlockComment(u32),
    Str,
    /// Number of `#`s that close the raw string.
    RawStr(usize),
}

/// Split `content` into [`Line`]s, classifying every character as code,
/// comment, or literal.  Handles `//`, nested `/* */`, `"…"` with
/// escapes and line continuations, `r#"…"#`, char literals vs
/// lifetimes (`'a'` vs `'a`).
fn scan(content: &str) -> Vec<Line> {
    let chars: Vec<char> = content.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut nostr = String::new();
    let mut comment = String::new();
    let mut mode = Mode::Code;
    let mut prev_code_char = '\n';
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            lines.push(Line {
                code: std::mem::take(&mut code),
                nostr: std::mem::take(&mut nostr),
                comment: std::mem::take(&mut comment),
            });
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Code;
            }
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = if i + 1 < n { chars[i + 1] } else { '\0' };
                if c == '/' && next == '/' {
                    mode = Mode::LineComment;
                    comment.push_str("//");
                    i += 2;
                } else if c == '/' && next == '*' {
                    mode = Mode::BlockComment(1);
                    comment.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    mode = Mode::Str;
                    code.push('"');
                    nostr.push('"');
                    prev_code_char = '"';
                    i += 1;
                } else if c == 'r'
                    && !is_ident(prev_code_char)
                    && raw_str_hashes(&chars, i + 1).is_some()
                {
                    // raw string r"…" / r#"…"# — blank the contents
                    let hashes = raw_str_hashes(&chars, i + 1).unwrap();
                    mode = Mode::RawStr(hashes);
                    for _ in 0..(1 + hashes + 1) {
                        code.push('r');
                        nostr.push('r');
                    }
                    prev_code_char = '"';
                    i += 1 + hashes + 1; // r, hashes, opening quote
                } else if c == '\'' {
                    // char literal or lifetime?
                    if next == '\\' {
                        // escaped char literal: consume to closing quote
                        code.push('\'');
                        nostr.push('\'');
                        i += 2;
                        while i < n && chars[i] != '\'' && chars[i] != '\n' {
                            code.push(chars[i]);
                            i += 1;
                        }
                        if i < n && chars[i] == '\'' {
                            code.push('\'');
                            nostr.push('\'');
                            i += 1;
                        }
                    } else if i + 2 < n && chars[i + 2] == '\'' && next != '\'' {
                        // plain char literal 'x'
                        code.push('\'');
                        code.push(next);
                        code.push('\'');
                        nostr.push_str("' '");
                        i += 3;
                    } else {
                        // lifetime tick
                        code.push('\'');
                        nostr.push('\'');
                        i += 1;
                    }
                    prev_code_char = '\'';
                } else {
                    code.push(c);
                    nostr.push(c);
                    prev_code_char = c;
                    i += 1;
                }
            }
            Mode::LineComment => {
                comment.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                let next = if i + 1 < n { chars[i + 1] } else { '\0' };
                if c == '*' && next == '/' {
                    comment.push_str("*/");
                    mode = if depth == 1 { Mode::Code } else { Mode::BlockComment(depth - 1) };
                    i += 2;
                } else if c == '/' && next == '*' {
                    comment.push_str("/*");
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    // escape; a trailing `\` before the newline is a
                    // line continuation — leave the newline unconsumed
                    code.push('\\');
                    nostr.push(' ');
                    i += 1;
                    if i < n && chars[i] != '\n' {
                        code.push(chars[i]);
                        nostr.push(' ');
                        i += 1;
                    }
                } else if c == '"' {
                    code.push('"');
                    nostr.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    code.push(c);
                    nostr.push(' ');
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i + 1, hashes) {
                    for _ in 0..(1 + hashes) {
                        code.push('"');
                        nostr.push('"');
                    }
                    mode = Mode::Code;
                    i += 1 + hashes;
                } else {
                    code.push(c);
                    nostr.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() || !nostr.is_empty() {
        lines.push(Line { code, nostr, comment });
    }
    lines
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// After an `r` at `chars[at - 1]`: `Some(h)` if `#`*h then `"` follows
/// (a raw string opener), else `None`.
fn raw_str_hashes(chars: &[char], mut at: usize) -> Option<usize> {
    let mut hashes = 0;
    while at < chars.len() && chars[at] == '#' {
        hashes += 1;
        at += 1;
    }
    if at < chars.len() && chars[at] == '"' {
        Some(hashes)
    } else {
        None
    }
}

fn closes_raw(chars: &[char], at: usize, hashes: usize) -> bool {
    (0..hashes).all(|k| at + k < chars.len() && chars[at + k] == '#')
}

/// Word-boundary token search: `needle` in `haystack` with no
/// identifier character on either side.
fn has_token(haystack: &str, needle: &str) -> bool {
    let bytes = haystack.as_bytes();
    let mut from = 0;
    while let Some(p) = haystack[from..].find(needle) {
        let abs = from + p;
        let end = abs + needle.len();
        let before_ok = abs == 0 || !is_ident(bytes[abs - 1] as char);
        let after_ok = end >= haystack.len() || !is_ident(bytes[end] as char);
        if before_ok && after_ok {
            return true;
        }
        from = abs + 1;
    }
    false
}

/// Does this line contain an `unsafe` *site* (block, fn, impl) —
/// excluding `unsafe fn(...)` fn-pointer types, which declare no
/// obligation at the use site?
fn has_unsafe_site(code: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(p) = code[from..].find("unsafe") {
        let abs = from + p;
        let end = abs + "unsafe".len();
        let before_ok = abs == 0 || !is_ident(bytes[abs - 1] as char);
        let after_ok = end >= code.len() || !is_ident(bytes[end] as char);
        if before_ok && after_ok {
            let rest = code[end..].trim_start();
            let fn_ptr = rest
                .strip_prefix("fn")
                .map(|r| r.trim_start().starts_with('('))
                .unwrap_or(false);
            if !fn_ptr {
                return true;
            }
        }
        from = abs + 1;
    }
    false
}

/// An `unsafe` site is covered when a `SAFETY:` comment sits on the
/// same line, or the contiguous comment block directly above it holds
/// `SAFETY:` / `# Safety`, or the immediately preceding code line is
/// itself a covered unsafe line (one comment may document a run of
/// consecutive unsafe impls).  Attribute lines (`#[...]`) are skipped
/// while walking up; a blank line breaks the block.
fn unsafe_is_covered(lines: &[Line], idx: usize) -> bool {
    if lines[idx].comment.contains("SAFETY:") {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if l.is_comment_only() {
            if l.comment.contains("SAFETY:") || l.comment.contains("# Safety") {
                return true;
            }
            continue;
        }
        if l.code.trim().starts_with("#[") {
            continue;
        }
        if l.code.trim().is_empty() {
            return false; // blank line breaks the comment block
        }
        // group coverage: a covered unsafe line directly above extends
        // its comment to this one
        return has_unsafe_site(&l.nostr) && unsafe_is_covered(lines, j);
    }
    false
}

/// `tidy:allow(<lint>)` on the line itself or anywhere in the
/// contiguous comment block directly above it.
fn is_allowed(lines: &[Line], idx: usize, lint: &str) -> bool {
    let needle = format!("tidy:allow({lint})");
    if lines[idx].comment.contains(&needle) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if l.is_comment_only() {
            if l.comment.contains(&needle) {
                return true;
            }
            continue;
        }
        break;
    }
    false
}

/// The raw-lock idioms `lock-discipline` bans outside `sync_ext`.
/// Returns a description of the first match.
fn lock_violation(code: &str) -> Option<String> {
    for call in [".lock()", ".try_lock()", ".read()", ".write()"] {
        if let Some(p) = code.find(call) {
            let rest = &code[p + call.len()..];
            if rest.starts_with(".unwrap") || rest.starts_with(".expect") {
                return Some(format!("`{call}` followed by unwrap/expect"));
            }
        }
    }
    if (code.contains(".wait(") || code.contains(".wait_timeout("))
        && (code.contains(".unwrap") || code.contains(".expect("))
    {
        return Some("condvar wait combined with unwrap/expect".into());
    }
    if code.contains("unwrap_or_else") && code.contains("into_inner") {
        return Some("inline poison recovery (unwrap_or_else + into_inner)".into());
    }
    if code.contains("PoisonError") {
        return Some("ad-hoc PoisonError handling".into());
    }
    None
}

/// Run every per-file lint over one file.  `rel` is the repo-relative
/// path with forward slashes; it selects the path allowlists.
pub fn lint_file(rel: &str, content: &str) -> Vec<Violation> {
    let lines = scan(content);
    // only a top-level (unindented) `#[cfg(test)]` opens the test
    // region: by convention the test module is the last item in every
    // file, so everything after it is compiled for tests only.  An
    // indented `#[cfg(test)]` on a single helper fn does not exempt
    // the rest of its impl block.
    let test_start = lines
        .iter()
        .position(|l| l.code.starts_with("#[cfg(test)"))
        .unwrap_or(usize::MAX);
    let in_tests_dir = rel.starts_with("rust/tests/") || rel.starts_with("rust/benches/");
    let mut out = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        let in_test = in_tests_dir || i >= test_start;
        let nostr = &l.nostr;
        let lineno = i + 1;

        if has_unsafe_site(nostr)
            && !unsafe_is_covered(&lines, i)
            && !is_allowed(&lines, i, "safety-comment")
        {
            out.push(Violation {
                file: rel.into(),
                line: lineno,
                lint: "safety-comment",
                msg: "unsafe site without a `// SAFETY:` comment stating the invariant \
                      that makes it sound"
                    .into(),
            });
        }

        if nostr.contains("thread::spawn")
            && !in_test
            && rel != "rust/src/runtime/pool.rs"
            && rel != "rust/src/server/event.rs"
            && !is_allowed(&lines, i, "thread-spawn")
        {
            out.push(Violation {
                file: rel.into(),
                line: lineno,
                lint: "thread-spawn",
                msg: "thread::spawn outside runtime/pool.rs — route work through the \
                      shared Pool, or tidy:allow(thread-spawn) with a justification"
                    .into(),
            });
        }

        if rel != "rust/src/sync_ext.rs" && !is_allowed(&lines, i, "lock-discipline") {
            if let Some(what) = lock_violation(nostr) {
                out.push(Violation {
                    file: rel.into(),
                    line: lineno,
                    lint: "lock-discipline",
                    msg: format!(
                        "{what} — use sync_ext::lock_or_recover / wait_or_recover; \
                         sync_ext owns the poison-recovery policy"
                    ),
                });
            }
        }

        if rel.starts_with("rust/src/")
            && !rel.starts_with("rust/src/data/")
            && !in_test
            && (nostr.contains("try_generate(")
                || nostr.contains("load_csv(")
                || nostr.contains("load_npy(")
                || nostr.contains("parse_header(")
                || nostr.contains("NpyReader::open")
                || nostr.contains("File::open("))
            && !is_allowed(&lines, i, "data-source")
        {
            out.push(Violation {
                file: rel.into(),
                line: lineno,
                lint: "data-source",
                msg: "direct dataset access (try_generate / load_csv / load_npy / npy \
                      parsing / raw File::open) — route it through a URI-addressed \
                      DataSource or RowStore (rust/src/data/)"
                    .into(),
            });
        }

        if nostr.contains("Ordering::Relaxed")
            && !in_test
            && rel != "rust/src/telemetry.rs"
            && rel != "rust/src/server/cache.rs"
            && !is_allowed(&lines, i, "relaxed-ordering")
        {
            out.push(Violation {
                file: rel.into(),
                line: lineno,
                lint: "relaxed-ordering",
                msg: "Ordering::Relaxed outside the stat-counter allowlist — admission \
                      and registry atomics synchronise state; use SeqCst (or \
                      tidy:allow(relaxed-ordering) with a proof)"
                    .into(),
            });
        }
    }
    out
}

/// All string literals on a (comment-stripped) code line, in order.
fn quoted_strings(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = code;
    while let Some(start) = rest.find('"') {
        let tail = &rest[start + 1..];
        match tail.find('"') {
            Some(end) => {
                out.push(tail[..end].to_string());
                rest = &tail[end + 1..];
            }
            None => break,
        }
    }
    out
}

/// The `verb-coverage` cross-file check: dispatch match in
/// `server/mod.rs` vs `metrics::VERBS` vs the protocol doc block.
pub fn check_verbs(mod_content: &str, metrics_content: &str) -> Vec<Violation> {
    const MOD: &str = "rust/src/server/mod.rs";
    const METRICS: &str = "rust/src/server/metrics.rs";
    let mod_lines = scan(mod_content);
    let test_start = mod_lines
        .iter()
        .position(|l| l.code.starts_with("#[cfg(test)"))
        .unwrap_or(mod_lines.len());

    // dispatched verbs: non-test lines whose code starts `Some("` —
    // the `match parts.first()` arms; first literal only, so a guard
    // like `Some("stats") if ... == Some("reset")` yields `stats`
    let mut dispatched: Vec<(usize, String)> = Vec::new();
    for (i, l) in mod_lines.iter().enumerate().take(test_start) {
        if let Some(rest) = l.code.trim_start().strip_prefix("Some(\"") {
            if let Some(end) = rest.find('"') {
                let verb = rest[..end].to_string();
                if !verb.is_empty() && !dispatched.iter().any(|(_, v)| *v == verb) {
                    dispatched.push((i + 1, verb));
                }
            }
        }
    }

    // the VERBS const in metrics.rs: string literals from the line
    // holding `const VERBS` through the closing `];`
    let metrics_lines = scan(metrics_content);
    let mut verbs_const: Vec<String> = Vec::new();
    let mut verbs_line = 0usize;
    let mut in_const = false;
    for (i, l) in metrics_lines.iter().enumerate() {
        if !in_const && l.code.contains("const VERBS") {
            in_const = true;
            verbs_line = i + 1;
        }
        if in_const {
            verbs_const.extend(quoted_strings(&l.code));
            // `];` ends the initializer — a bare `]` would trip on the
            // `[&str; N]` type annotation on the declaration line
            if l.code.contains("];") {
                break;
            }
        }
    }

    // the protocol doc: the `//!` block at the top of server/mod.rs
    let doc_text: String = mod_content
        .lines()
        .filter(|l| l.trim_start().starts_with("//!"))
        .collect::<Vec<_>>()
        .join("\n");

    let mut out = Vec::new();
    if verbs_line == 0 {
        out.push(Violation {
            file: METRICS.into(),
            line: 1,
            lint: "verb-coverage",
            msg: "no `const VERBS` table found — per-verb counters are gone".into(),
        });
        return out;
    }
    for (line, verb) in &dispatched {
        if !verbs_const.iter().any(|v| v == verb) {
            out.push(Violation {
                file: MOD.into(),
                line: *line,
                lint: "verb-coverage",
                msg: format!(
                    "wire verb \"{verb}\" is dispatched here but has no counter in \
                     metrics::VERBS ({METRICS})"
                ),
            });
        }
        if !has_token(&doc_text, verb) {
            out.push(Violation {
                file: MOD.into(),
                line: *line,
                lint: "verb-coverage",
                msg: format!(
                    "wire verb \"{verb}\" is dispatched here but never mentioned in \
                     the //! protocol doc block"
                ),
            });
        }
    }
    for verb in &verbs_const {
        if !dispatched.iter().any(|(_, v)| v == verb) {
            out.push(Violation {
                file: METRICS.into(),
                line: verbs_line,
                lint: "verb-coverage",
                msg: format!(
                    "metrics::VERBS entry \"{verb}\" is never dispatched in {MOD} — \
                     dead counter or missing match arm"
                ),
            });
        }
    }
    out
}

/// Recursively collect `.rs` files under `dir` (sorted by the caller).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Walk `rust/src`, `rust/tests`, `rust/benches` under `root`, run
/// every lint, and return `(files_checked, violations)` sorted by
/// `(file, line)`.
pub fn check_repo(root: &Path) -> (usize, Vec<Violation>) {
    let mut files = Vec::new();
    for dir in ["rust/src", "rust/tests", "rust/benches"] {
        collect_rs(&root.join(dir), &mut files);
    }
    files.sort();
    let mut violations = Vec::new();
    let mut mod_rs = String::new();
    let mut metrics_rs = String::new();
    for path in &files {
        let rel: String = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let content = match fs::read_to_string(path) {
            Ok(c) => c,
            Err(e) => {
                violations.push(Violation {
                    file: rel,
                    line: 0,
                    lint: "safety-comment",
                    msg: format!("unreadable file: {e}"),
                });
                continue;
            }
        };
        violations.extend(lint_file(&rel, &content));
        if rel == "rust/src/server/mod.rs" {
            mod_rs = content;
        } else if rel == "rust/src/server/metrics.rs" {
            metrics_rs = content;
        }
    }
    if !mod_rs.is_empty() && !metrics_rs.is_empty() {
        violations.extend(check_verbs(&mod_rs, &metrics_rs));
    }
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    (files.len(), violations)
}

/// The workspace root: two levels above this crate's manifest.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("tools/tidy sits two levels under the workspace root")
        .to_path_buf()
}

fn main() {
    let (nfiles, violations) = check_repo(&repo_root());
    if violations.is_empty() {
        println!("tidy: ok — {nfiles} files clean under {} lints", LINT_NAMES.len());
        return;
    }
    for v in &violations {
        println!("{v}");
    }
    eprintln!(
        "tidy: {} violation(s) in {nfiles} files; see docs/INVARIANTS.md for \
         the policy and `tidy:allow(<lint>)` escape hatch",
        violations.len()
    );
    std::process::exit(1);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lints_of(rel: &str, src: &str) -> Vec<&'static str> {
        lint_file(rel, src).into_iter().map(|v| v.lint).collect()
    }

    // ---- scanner ----

    #[test]
    fn comments_and_strings_are_not_code() {
        // in a line comment, a doc comment, a block comment, a string
        for src in [
            "// unsafe { thread::spawn }\n",
            "/// .lock().unwrap() in prose\n",
            "/* unsafe */ let x = 1;\n",
            "let s = \"unsafe Ordering::Relaxed .lock().unwrap()\";\n",
            "let s = \"multi \\\n  line unsafe string\";\n",
        ] {
            assert_eq!(lints_of("rust/src/foo.rs", src), Vec::<&str>::new(), "{src:?}");
        }
    }

    #[test]
    fn nested_block_comments_and_char_literals() {
        let src = "/* outer /* unsafe inner */ still comment */ let c = '\"'; let l: &'static str = \"x\";\n";
        assert_eq!(lints_of("rust/src/foo.rs", src), Vec::<&str>::new());
        // lifetimes don't open char literals
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\n";
        assert_eq!(lints_of("rust/src/foo.rs", src), Vec::<&str>::new());
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let s = r#\"unsafe .lock().unwrap()\"#;\nlet t = r\"thread::spawn\";\n";
        assert_eq!(lints_of("rust/src/foo.rs", src), Vec::<&str>::new());
    }

    // ---- safety-comment ----

    #[test]
    fn uncommented_unsafe_is_flagged() {
        let v = lint_file("rust/src/foo.rs", "let x = unsafe { *p };\n");
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!((v[0].lint, v[0].line), ("safety-comment", 1));
    }

    #[test]
    fn safety_comment_above_or_inline_covers() {
        for src in [
            "// SAFETY: p is valid for reads\nlet x = unsafe { *p };\n",
            "let x = unsafe { *p }; // SAFETY: p is valid\n",
            "/// # Safety\n/// caller pins the frame\nunsafe fn f(p: *const u8) {}\n",
        ] {
            assert_eq!(lints_of("rust/src/foo.rs", src), Vec::<&str>::new(), "{src:?}");
        }
    }

    #[test]
    fn group_coverage_spans_consecutive_unsafe_impls() {
        let src = "// SAFETY: disjoint writes, T: Send moves values soundly\n\
                   unsafe impl<T: Send> Send for P<T> {}\n\
                   unsafe impl<T: Send> Sync for P<T> {}\n";
        assert_eq!(lints_of("rust/src/foo.rs", src), Vec::<&str>::new());
        // ... but a blank line breaks the group
        let src = "// SAFETY: only the first\n\
                   unsafe impl Send for P {}\n\n\
                   unsafe impl Sync for P {}\n";
        assert_eq!(lints_of("rust/src/foo.rs", src), vec!["safety-comment"]);
    }

    #[test]
    fn unsafe_fn_pointer_types_are_not_sites() {
        let src = "struct J { call: unsafe fn(*const (), usize) }\n";
        assert_eq!(lints_of("rust/src/foo.rs", src), Vec::<&str>::new());
        // ...but an unsafe fn *definition* is one
        let src = "unsafe fn call_erased(ctx: *const ()) {}\n";
        assert_eq!(lints_of("rust/src/foo.rs", src), vec!["safety-comment"]);
    }

    // ---- thread-spawn ----

    #[test]
    fn spawn_is_flagged_outside_the_pool() {
        let src = "let h = std::thread::spawn(|| {});\n";
        assert_eq!(lints_of("rust/src/foo.rs", src), vec!["thread-spawn"]);
        assert_eq!(lints_of("rust/src/runtime/pool.rs", src), Vec::<&str>::new());
        // the evented accept core owns the one sanctioned accept-path spawn
        assert_eq!(lints_of("rust/src/server/event.rs", src), Vec::<&str>::new());
        assert_eq!(lints_of("rust/tests/foo.rs", src), Vec::<&str>::new());
        assert_eq!(lints_of("rust/benches/foo.rs", src), Vec::<&str>::new());
        let in_tests = format!("#[cfg(test)]\nmod tests {{\n{src}}}\n");
        assert_eq!(lints_of("rust/src/foo.rs", &in_tests), Vec::<&str>::new());
    }

    // ---- lock-discipline ----

    #[test]
    fn raw_lock_unwraps_are_flagged() {
        for src in [
            "let g = m.lock().unwrap();\n",
            "let g = m.lock().expect(\"poisoned\");\n",
            "let g = m.try_lock().unwrap();\n",
            "let g = rw.read().unwrap();\n",
            "let g = rw.write().unwrap();\n",
            "let g = cv.wait(g).unwrap();\n",
            "let g = m.lock().unwrap_or_else(|e| e.into_inner());\n",
            "fn f(e: PoisonError<T>) {}\n",
        ] {
            assert_eq!(lints_of("rust/src/server/foo.rs", src), vec!["lock-discipline"], "{src:?}");
            // sync_ext owns the policy and is exempt
            assert_eq!(lints_of("rust/src/sync_ext.rs", src), Vec::<&str>::new(), "{src:?}");
        }
    }

    #[test]
    fn helper_lock_calls_and_plain_expect_are_fine() {
        for src in [
            "let mut inner = self.lock();\n",              // registry helper, not Mutex::lock
            "let work = slot.take().expect(\"armed\");\n", // Option::expect
            "state.jobs.wait(id, None);\n",                // registry wait, no unwrap
            "barrier.wait();\n",                           // Barrier::wait returns no Result
        ] {
            assert_eq!(lints_of("rust/src/server/foo.rs", src), Vec::<&str>::new(), "{src:?}");
        }
    }

    // ---- data-source ----

    #[test]
    fn direct_generation_is_flagged_outside_data() {
        let src = "let x = synth::try_generate(name, seed)?;\nlet y = load_csv(path)?;\n";
        let v = lint_file("rust/src/main.rs", src);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|v| v.lint == "data-source"));
        assert_eq!(lints_of("rust/src/data/source.rs", src), Vec::<&str>::new());
        assert_eq!(lints_of("rust/tests/foo.rs", src), Vec::<&str>::new());
    }

    #[test]
    fn raw_file_and_npy_parsing_are_flagged_outside_data() {
        let src = "let f = std::fs::File::open(path)?;\n\
                   let d = load_npy(path)?;\n\
                   let r = NpyReader::open(path)?;\n\
                   let h = parse_header(&f, path)?;\n";
        let v = lint_file("rust/src/server/mod.rs", src);
        assert_eq!(v.len(), 4, "{v:?}");
        assert!(v.iter().all(|v| v.lint == "data-source"));
        // the data layer itself, tests and benches are exempt
        assert_eq!(lints_of("rust/src/data/npy.rs", src), Vec::<&str>::new());
        assert_eq!(lints_of("rust/tests/foo.rs", src), Vec::<&str>::new());
        assert_eq!(lints_of("rust/benches/foo.rs", src), Vec::<&str>::new());
        // the published header probe is the sanctioned pre-admission
        // API — callable from the CLI and the server
        let ok = "let h = obpam::data::npy::read_header(std::path::Path::new(p))?;\n";
        assert_eq!(lints_of("rust/src/main.rs", ok), Vec::<&str>::new());
        // an annotated escape hatch still works
        let allowed = "// tidy:allow(data-source) — probing a non-dataset file\n\
                       let f = std::fs::File::open(path)?;\n";
        assert_eq!(lints_of("rust/src/server/mod.rs", allowed), Vec::<&str>::new());
    }

    // ---- relaxed-ordering ----

    #[test]
    fn relaxed_ordering_is_flagged_outside_counters() {
        let src = "self.used.fetch_add(1, Ordering::Relaxed);\n";
        assert_eq!(lints_of("rust/src/server/mod.rs", src), vec!["relaxed-ordering"]);
        assert_eq!(lints_of("rust/src/telemetry.rs", src), Vec::<&str>::new());
        assert_eq!(lints_of("rust/src/server/cache.rs", src), Vec::<&str>::new());
        // SeqCst is always fine
        let src = "self.used.fetch_add(1, Ordering::SeqCst);\n";
        assert_eq!(lints_of("rust/src/server/mod.rs", src), Vec::<&str>::new());
    }

    // ---- tidy:allow ----

    #[test]
    fn tidy_allow_suppresses_on_line_or_block_above() {
        for src in [
            "let h = std::thread::spawn(f); // tidy:allow(thread-spawn) — owned+joined\n",
            "// tidy:allow(thread-spawn) — accept loop,\n// owned and joined on shutdown\nlet h = std::thread::spawn(f);\n",
        ] {
            assert_eq!(lints_of("rust/src/foo.rs", src), Vec::<&str>::new(), "{src:?}");
        }
        // the wrong lint name does not suppress
        let src = "// tidy:allow(safety-comment)\nlet h = std::thread::spawn(f);\n";
        assert_eq!(lints_of("rust/src/foo.rs", src), vec!["thread-spawn"]);
        // a blank line detaches the comment block
        let src = "// tidy:allow(thread-spawn)\n\nlet h = std::thread::spawn(f);\n";
        assert_eq!(lints_of("rust/src/foo.rs", src), vec!["thread-spawn"]);
    }

    // ---- verb-coverage ----

    const METRICS_OK: &str = "pub const VERBS: [&str; 2] = [\"ping\", \"stats\"];\n";

    #[test]
    fn verb_missing_counter_or_doc_is_flagged() {
        let m = "//! * `ping` — liveness probe\n\
                 fn dispatch() {\n    match v {\n        Some(\"ping\") => {}\n        Some(\"stats\") => {}\n    }\n}\n";
        // stats has a counter but no doc mention
        let v = check_verbs(m, METRICS_OK);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("\"stats\"") && v[0].msg.contains("protocol doc"), "{v:?}");

        // a verb with no VERBS entry at all
        let m2 = "//! `ping`, `stats` and `flush` verbs\n\
                  fn dispatch() {\n    match v {\n        Some(\"ping\") => {}\n        Some(\"stats\") => {}\n        Some(\"flush\") => {}\n    }\n}\n";
        let v = check_verbs(m2, METRICS_OK);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("\"flush\"") && v[0].msg.contains("VERBS"), "{v:?}");
    }

    #[test]
    fn serving_verb_without_counter_is_flagged_even_when_documented() {
        // the v6 failure mode this lint exists for: a new serving verb
        // (promote/assign-shaped) lands with a dispatch arm and a doc
        // mention but nobody extends metrics::VERBS — the uncounted
        // verb must be caught, and the violation must point at VERBS
        // specifically (not at the doc, which is fine)
        let m = "//! `ping`, `stats` and the `assign` read path\n\
                 fn dispatch() {\n    match v {\n        Some(\"ping\") => {}\n        Some(\"stats\") => {}\n        Some(\"assign\") => {}\n    }\n}\n";
        let v = check_verbs(m, METRICS_OK);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("\"assign\"") && v[0].msg.contains("VERBS"), "{v:?}");
        assert_eq!(v[0].file, "rust/src/server/mod.rs");
        assert!(!v.iter().any(|x| x.msg.contains("protocol doc")), "doc mention is fine: {v:?}");
    }

    #[test]
    fn dead_verbs_entries_are_flagged() {
        let m = "//! `ping` only\nfn dispatch() {\n    match v {\n        Some(\"ping\") => {}\n    }\n}\n";
        let v = check_verbs(m, METRICS_OK);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("never dispatched"), "{v:?}");
        assert_eq!(v[0].file, "rust/src/server/metrics.rs");
    }

    #[test]
    fn guarded_match_arms_yield_the_arm_verb_only() {
        let m = "//! `stats` with a reset form\n\
                 fn dispatch() {\n    match v {\n        Some(\"stats\") if kv == Some(\"reset\") => {}\n        Some(\"stats\") => {}\n    }\n}\n";
        let metrics = "pub const VERBS: [&str; 1] = [\"stats\"];\n";
        assert_eq!(check_verbs(m, metrics), Vec::<Violation>::new());
    }

    #[test]
    fn dispatch_arms_in_test_modules_are_ignored() {
        let m = "//! `ping`\nfn dispatch() {\n    match v {\n        Some(\"ping\") => {}\n    }\n}\n\
                 #[cfg(test)]\nmod tests {\n    fn t(v: Option<&str>) {\n        match v {\n            Some(\"bogus\") => {}\n            _ => {}\n        }\n    }\n}\n";
        let metrics = "pub const VERBS: [&str; 1] = [\"ping\"];\n";
        assert_eq!(check_verbs(m, metrics), Vec::<Violation>::new());
    }

    // ---- the repo itself ----

    #[test]
    fn repo_is_tidy() {
        let (nfiles, violations) = check_repo(&repo_root());
        assert!(nfiles > 10, "the repo walk found only {nfiles} files — wrong root?");
        assert!(
            violations.is_empty(),
            "tidy violations:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
